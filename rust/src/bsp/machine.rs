//! The BSP multiprocessor runtime.
//!
//! Runs an SPMD closure on `p` virtual processors (one OS thread each),
//! provides the bulk-synchronous all-to-all exchange the algorithms need
//! (the realization of superstep-1 `Put`s in Alg. 2.2/2.3 — all Puts of a
//! superstep between a pair of processors form one packet), and records
//! the per-processor cost ledger.
//!
//! This is the substitute for MPI + Snellius: the exchange moves real
//! data between real threads through shared memory, with the same
//! structure (packets, h-relations, barrier semantics) the paper's MPI
//! implementation has over Infiniband. Wall-clock timings at small p are
//! measured on this runtime; paper-scale p is extrapolated through
//! [`crate::costmodel`] from the exact ledgers recorded here.
//!
//! # Failure model
//!
//! Sessions are *abortable*: the barrier is a cancellable rendezvous
//! (count + generation + abort flag over a condvar). Any rank that
//! panics, detects a protocol violation, or times out waiting for its
//! peers flips the session to aborted; every current and future waiter
//! then wakes with `SessionAborted` instead of blocking forever, unwinds
//! (draining its mailbox row on the way out), and the session as a whole
//! returns a typed [`BspFailure`] from [`try_run_spmd`] /
//! [`try_run_spmd_with`] naming every genuinely failing rank, the
//! superstep label, and the cause ([`FailureCause`]). [`run_spmd`] is
//! the panicking wrapper. Deterministic fault injection for testing this
//! machinery lives in [`crate::bsp::fault`]; always-on cheap detection
//! (packet counts against the compiled schedule, the occupied-slot
//! invariant, symmetric pairwise lengths) turns injected — or real —
//! protocol corruption into aborts.
//!
//! Under `--cfg loom` the private `sync` shim swaps the standard-library
//! synchronization primitives for [loom](https://docs.rs/loom)'s
//! model-checked versions, and the `loom_model` tests at the bottom of
//! this file explore EVERY interleaving of the mailbox pointer-swap
//! protocol, the arena session try-lock, and the cancellable barrier's
//! abort path (CI's `loom` job). The dependency-free companion checker
//! lives in [`crate::analysis::interleave`].

// This file is one of the three allocation-audited hot modules (see
// clippy.toml): the steady-state paths (`exchange_swap`,
// `pairwise_exchange`) must stay free of allocation-prone calls; the
// session-setup, failure-path, and test code that legitimately
// allocates carries explicit `#[allow]`s with justifications.
#![deny(clippy::disallowed_methods, clippy::disallowed_macros)]

use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::Arc;
use std::time::Duration;

use sync::{Condvar, Mutex};

use super::fault::{FaultKind, FaultPlan};
use super::ledger::{CostReport, ProcLedger, SuperstepKind};
use crate::fft::C64;

/// Synchronization primitives behind the runtime: the standard library
/// by default, loom's model-checked doubles under `--cfg loom`. The
/// cancellable barrier below is hand-rolled over these (one
/// implementation for both worlds; the deadline arm is std-only because
/// loom models logical time, not wall-clock time).
mod sync {
    #[cfg(not(loom))]
    pub(crate) use std::sync::{Condvar, Mutex};

    #[cfg(loom)]
    pub(crate) use loom::sync::{Condvar, Mutex};
}

/// Lock a mutex, riding through poisoning: a panicking rank may unwind
/// while holding a mailbox-slot or registry lock, and the surviving
/// ranks (and the post-session drain) must still be able to inspect the
/// contents — an `Option<Vec<C64>>` is structurally valid regardless of
/// where the holder died.
#[cfg(not(loom))]
fn lock_robust<T>(m: &Mutex<T>) -> std::sync::MutexGuard<'_, T> {
    m.lock().unwrap_or_else(std::sync::PoisonError::into_inner)
}

#[cfg(loom)]
fn lock_robust<T>(m: &Mutex<T>) -> loom::sync::MutexGuard<'_, T> {
    m.lock().unwrap()
}

/// Why a barrier wait returned without the rendezvous completing.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub(crate) enum BarrierWaitError {
    /// The session was aborted (by this or another rank).
    Aborted,
    /// This waiter exceeded the superstep deadline.
    TimedOut,
}

struct BarrierState {
    count: usize,
    generation: usize,
    aborted: bool,
}

/// A cancellable rendezvous barrier: `std::sync::Barrier` semantics plus
/// an `abort` switch. Once aborted, every current waiter is released
/// with `Err(Aborted)` and every future `wait` returns `Err(Aborted)`
/// immediately — the session is dead and stays dead (no reset), which is
/// exactly what lets a panicking rank's peers unwind instead of
/// deadlocking.
pub(crate) struct CancellableBarrier {
    state: Mutex<BarrierState>,
    cvar: Condvar,
    n: usize,
}

impl CancellableBarrier {
    pub(crate) fn new(n: usize) -> Self {
        CancellableBarrier {
            state: Mutex::new(BarrierState { count: 0, generation: 0, aborted: false }),
            cvar: Condvar::new(),
            n,
        }
    }

    /// Flip the session to aborted and wake every waiter. Idempotent.
    pub(crate) fn abort(&self) {
        let mut st = lock_robust(&self.state);
        st.aborted = true;
        drop(st);
        self.cvar.notify_all();
    }

    /// Wait for all `n` participants (or abort/timeout). `deadline`
    /// bounds *this* wait; `None` waits forever. The deadline arm is
    /// compiled out under loom (loom has no wall clock); loom models
    /// exercise the abort path, the timeout path is a std-only refinement
    /// of it.
    pub(crate) fn wait(&self, deadline: Option<Duration>) -> Result<(), BarrierWaitError> {
        let mut st = lock_robust(&self.state);
        if st.aborted {
            return Err(BarrierWaitError::Aborted);
        }
        let generation = st.generation;
        st.count += 1;
        if st.count == self.n {
            st.count = 0;
            st.generation += 1;
            drop(st);
            self.cvar.notify_all();
            return Ok(());
        }
        #[cfg(not(loom))]
        {
            match deadline {
                None => loop {
                    st = self.cvar.wait(st).unwrap_or_else(std::sync::PoisonError::into_inner);
                    if st.aborted {
                        return Err(BarrierWaitError::Aborted);
                    }
                    if st.generation != generation {
                        return Ok(());
                    }
                },
                Some(d) => {
                    let start = std::time::Instant::now();
                    loop {
                        let left = d.saturating_sub(start.elapsed());
                        if left.is_zero() {
                            // Abandoning the rendezvous corrupts the
                            // count, but the caller aborts the session
                            // immediately, so the barrier is dead anyway.
                            return Err(BarrierWaitError::TimedOut);
                        }
                        let (g, _timeout) = self
                            .cvar
                            .wait_timeout(st, left)
                            .unwrap_or_else(std::sync::PoisonError::into_inner);
                        st = g;
                        if st.aborted {
                            return Err(BarrierWaitError::Aborted);
                        }
                        if st.generation != generation {
                            return Ok(());
                        }
                    }
                }
            }
        }
        #[cfg(loom)]
        {
            let _ = deadline;
            loop {
                st = self.cvar.wait(st).unwrap();
                if st.aborted {
                    return Err(BarrierWaitError::Aborted);
                }
                if st.generation != generation {
                    return Ok(());
                }
            }
        }
    }
}

/// Panic payload used to unwind a rank out of an aborted session. The
/// catcher in `try_run_spmd_with` recognizes it and does NOT record it
/// as a failure: the rank is a victim of the abort, not its cause.
struct SessionAborted;

/// Unwind out of an aborted session.
fn abort_unwind() -> ! {
    std::panic::panic_any(SessionAborted)
}

/// Why a rank failed.
#[derive(Clone, Debug, PartialEq, Eq)]
#[non_exhaustive]
pub enum FailureCause {
    /// The rank's closure panicked (message captured when stringy).
    Panic(String),
    /// The rank detected a protocol violation (bad packet count,
    /// occupied mailbox slot, asymmetric pairing, ...).
    Violation(String),
    /// The rank exceeded the superstep deadline waiting for its peers.
    Timeout,
}

impl std::fmt::Display for FailureCause {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            FailureCause::Panic(msg) => write!(f, "panicked: {msg}"),
            FailureCause::Violation(msg) => write!(f, "protocol violation: {msg}"),
            FailureCause::Timeout => write!(f, "timed out waiting for peers"),
        }
    }
}

/// One rank's failure record: who, where, why.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct RankFailure {
    pub rank: usize,
    /// Label of the superstep (or barrier sync) the rank failed in.
    pub superstep: &'static str,
    pub cause: FailureCause,
}

impl std::fmt::Display for RankFailure {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "BSP processor {} at superstep '{}' {}", self.rank, self.superstep, self.cause)
    }
}

/// A failed SPMD session: every rank that *genuinely* failed (panicked,
/// detected a violation, or timed out), in detection order. Ranks that
/// merely woke from the aborted barrier are victims and are not listed.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct BspFailure {
    pub failures: Vec<RankFailure>,
}

impl BspFailure {
    /// The first-detected failure (the registry is in detection order).
    pub fn first(&self) -> &RankFailure {
        &self.failures[0]
    }

    /// Whether any recorded failure is a deadline timeout.
    pub fn timed_out(&self) -> bool {
        self.failures.iter().any(|f| f.cause == FailureCause::Timeout)
    }
}

impl std::fmt::Display for BspFailure {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        for (i, failure) in self.failures.iter().enumerate() {
            if i > 0 {
                write!(f, "; ")?;
            }
            write!(f, "{failure}")?;
        }
        Ok(())
    }
}

impl std::error::Error for BspFailure {}

/// Default per-wait superstep deadline: generous enough that no
/// legitimate superstep at test/bench scale comes near it, small enough
/// that an accidental deadlock surfaces as a typed failure instead of a
/// wedged process.
pub const DEFAULT_SUPERSTEP_DEADLINE: Duration = Duration::from_secs(120);

/// Default batch pipeline depth: depth-2 software pipelining (entry
/// `i + 1`'s superstep-0 compute overlaps entry `i`'s in-flight
/// all-to-all packets). Depth 1 is the strictly-sequential oracle.
pub const DEFAULT_PIPELINE_DEPTH: usize = 2;

/// Session knobs for [`try_run_spmd_with`]: the per-barrier-wait
/// deadline, an optional scripted [`FaultPlan`], and the batch pipeline
/// depth. The default (generous deadline, no faults, depth-2 pipeline)
/// is what every production path uses; the fault plane costs one
/// `Option` test per communication superstep when disarmed.
///
/// Construct via [`ExecOptions::builder`]:
///
/// ```
/// use fftu::bsp::ExecOptions;
/// let opts = ExecOptions::builder().deadline_ms(5_000).pipeline(1).build();
/// assert_eq!(opts.pipeline, 1);
/// ```
#[derive(Clone, Debug)]
pub struct ExecOptions {
    /// Upper bound on any single barrier wait; `None` waits forever.
    pub deadline: Option<Duration>,
    /// Scripted faults (testing / chaos engineering only).
    pub faults: Option<Arc<FaultPlan>>,
    /// Batch pipeline depth: 1 = strictly sequential (the differential
    /// oracle), >= 2 = depth-2 split-phase pipelining (the engine keeps
    /// at most two entries in flight regardless of larger values).
    pub pipeline: usize,
}

/// The pre-PR-9 name for [`ExecOptions`], kept for the BSP-layer call
/// sites that predate the unified builder.
pub type SpmdOptions = ExecOptions;

impl Default for ExecOptions {
    fn default() -> Self {
        ExecOptions {
            deadline: Some(DEFAULT_SUPERSTEP_DEADLINE),
            faults: None,
            pipeline: DEFAULT_PIPELINE_DEPTH,
        }
    }
}

impl ExecOptions {
    /// Start a builder from the defaults.
    pub fn builder() -> ExecOptionsBuilder {
        ExecOptionsBuilder { opts: ExecOptions::default() }
    }

    /// Builder: set the per-wait superstep deadline.
    pub fn with_deadline(mut self, deadline: Duration) -> Self {
        self.deadline = Some(deadline);
        self
    }

    /// Builder: wait forever at barriers (pre-PR-8 behavior).
    pub fn without_deadline(mut self) -> Self {
        self.deadline = None;
        self
    }

    /// Builder: attach a scripted fault plan.
    pub fn inject(mut self, faults: FaultPlan) -> Self {
        self.faults = Some(Arc::new(faults));
        self
    }

    /// Builder: set the batch pipeline depth (clamped to >= 1).
    pub fn with_pipeline(mut self, depth: usize) -> Self {
        self.pipeline = depth.max(1);
        self
    }
}

/// Fluent builder for [`ExecOptions`] — the one surface for the
/// deadline, fault-injection, and pipeline-depth knobs.
#[derive(Clone, Debug)]
pub struct ExecOptionsBuilder {
    opts: ExecOptions,
}

impl ExecOptionsBuilder {
    /// Per-wait superstep deadline in milliseconds.
    pub fn deadline_ms(mut self, ms: u64) -> Self {
        self.opts.deadline = Some(Duration::from_millis(ms));
        self
    }

    /// Per-wait superstep deadline as a [`Duration`].
    pub fn deadline(mut self, deadline: Duration) -> Self {
        self.opts.deadline = Some(deadline);
        self
    }

    /// Wait forever at barriers.
    pub fn no_deadline(mut self) -> Self {
        self.opts.deadline = None;
        self
    }

    /// Attach a scripted fault plan.
    pub fn faults(mut self, faults: FaultPlan) -> Self {
        self.opts.faults = Some(Arc::new(faults));
        self
    }

    /// Batch pipeline depth: 1 = strictly sequential oracle, 2 (the
    /// default) = split-phase depth-2 pipelining. Clamped to >= 1.
    pub fn pipeline(mut self, depth: usize) -> Self {
        self.opts.pipeline = depth.max(1);
        self
    }

    /// Finish the builder.
    pub fn build(self) -> ExecOptions {
        self.opts
    }
}

/// Shared state for one SPMD run.
struct Shared {
    p: usize,
    /// Mailbox slot (sender, receiver) -> packet in flight.
    slots: Vec<Mutex<Option<Vec<C64>>>>,
    barrier: CancellableBarrier,
    /// Failure registry, in detection order.
    failures: Mutex<Vec<RankFailure>>,
    deadline: Option<Duration>,
    faults: Option<Arc<FaultPlan>>,
    pipeline: usize,
}

impl Shared {
    // Cold failure path; the push is once-per-failed-session, not
    // steady state.
    #[allow(clippy::disallowed_methods)]
    fn record_failure(&self, rank: usize, superstep: &'static str, cause: FailureCause) {
        lock_robust(&self.failures).push(RankFailure { rank, superstep, cause });
    }
}

/// Receive-count expectation for one all-to-all, compiled from the
/// schedule (the same per-pair counts the `analysis` module's
/// FlowConservation lint verifies statically).
enum Expect<'e> {
    /// No compiled expectation (legacy paths).
    None,
    /// Every non-self packet has exactly this many words (FFTU's
    /// Eq. 2.12 uniform packets).
    Uniform(usize),
    /// `counts[i]` words expected from sender `i`.
    PerSender(&'e [usize]),
}

impl Expect<'_> {
    #[inline]
    fn of(&self, i: usize) -> Option<usize> {
        match self {
            Expect::None => None,
            Expect::Uniform(w) => Some(*w),
            Expect::PerSender(counts) => Some(counts[i]),
        }
    }
}

/// A split-phase exchange started by [`Ctx::exchange_start`] whose
/// packets are in flight until the matching [`Ctx::exchange_finish`].
struct PendingExchange {
    label: &'static str,
    /// Words deposited at start time (the `h_out` half of the ledger
    /// charge, computed before the buffers were taken by the mailbox).
    out_words: usize,
}

/// Per-processor execution context handed to the SPMD closure.
pub struct Ctx<'a> {
    rank: usize,
    shared: &'a Shared,
    /// Communication supersteps completed by this rank (fault-plan
    /// coordinates are `(rank, comm_step)`).
    comm_step: usize,
    /// In-flight split-phase exchange, if any (at most one).
    pending: Option<PendingExchange>,
    pub ledger: ProcLedger,
}

impl<'a> Ctx<'a> {
    /// This processor's rank `s in [p]`.
    #[inline]
    pub fn rank(&self) -> usize {
        self.rank
    }

    /// Number of processors.
    #[inline]
    pub fn nprocs(&self) -> usize {
        self.shared.p
    }

    /// Batch pipeline depth requested for this session (1 = strictly
    /// sequential oracle; >= 2 enables the depth-2 split-phase pipeline
    /// in the batch drivers).
    #[inline]
    pub fn pipeline_depth(&self) -> usize {
        self.shared.pipeline
    }

    /// Whether a split-phase exchange is currently in flight on this
    /// rank (started but not yet finished).
    #[inline]
    pub fn exchange_in_flight(&self) -> bool {
        self.pending.is_some()
    }

    /// Begin a computation superstep (cost-accounting only; computation
    /// supersteps need no synchronization with one-sided communication,
    /// which is why the paper charges `l` only for communication).
    pub fn begin_comp(&mut self, label: &'static str) {
        self.ledger.begin(SuperstepKind::Computation, label);
    }

    /// Charge flops to the current computation superstep.
    pub fn charge_flops(&mut self, flops: f64) {
        self.ledger.charge_flops(flops);
    }

    /// Record a failure for this rank, abort the session, and unwind.
    /// Cold path: runs at most once per session.
    #[allow(clippy::disallowed_methods)]
    fn fail(&self, superstep: &'static str, cause: FailureCause) -> ! {
        self.shared.record_failure(self.rank, superstep, cause);
        self.shared.barrier.abort();
        abort_unwind()
    }

    /// Wait at the cancellable barrier under the session deadline. On
    /// abort, unwind silently (another rank recorded the cause); on
    /// timeout, record a `Timeout` failure for this rank (the stalled
    /// peer is elsewhere — possibly not even at a barrier — so the
    /// detecting rank reports) and abort.
    fn sync_wait(&self, superstep: &'static str) {
        match self.shared.barrier.wait(self.shared.deadline) {
            Ok(()) => {}
            Err(BarrierWaitError::Aborted) => abort_unwind(),
            Err(BarrierWaitError::TimedOut) => self.fail(superstep, FailureCause::Timeout),
        }
    }

    /// Apply this rank's scripted pre-deposit faults for communication
    /// superstep `step` (panic, delay, drop/truncate an outgoing
    /// packet). Returns whether the packet to `pair_to` (pairwise mode)
    /// should be dropped. Cold unless a fault plan is armed.
    #[allow(clippy::disallowed_methods, clippy::disallowed_macros)]
    fn apply_pre_faults(
        &self,
        label: &'static str,
        step: usize,
        bufs: &mut [Vec<C64>],
        pair_to: Option<usize>,
    ) -> bool {
        let Some(plan) = self.shared.faults.as_deref() else { return false };
        let mut drop_pair = false;
        for kind in plan.faults_for(self.rank, step) {
            match kind {
                // Recorded explicitly (not a plain `panic!`) so the
                // failure is attributed to the *exchange* label even
                // when the fault fires inside `exchange_start`, where
                // the ledger's current superstep is still the
                // overlapped computation. The message carries the comm
                // step, which for pipelined batches is the in-flight
                // entry's exchange index.
                FaultKind::Panic => self.fail(
                    label,
                    FailureCause::Panic(format!(
                        "injected fault: processor {} panics at communication superstep {} ('{}')",
                        self.rank, step, label
                    )),
                ),
                FaultKind::Delay(d) => std::thread::sleep(*d),
                FaultKind::DropPacket { to } => match pair_to {
                    Some(partner) if *to == partner => drop_pair = true,
                    Some(_) => {}
                    None => {
                        if let Some(b) = bufs.get_mut(*to) {
                            b.clear();
                        }
                    }
                },
                FaultKind::TruncatePacket { to, keep } => match pair_to {
                    Some(partner) if *to == partner => bufs[0].truncate(*keep),
                    Some(_) => {}
                    None => {
                        if let Some(b) = bufs.get_mut(*to) {
                            b.truncate(*keep);
                        }
                    }
                },
                FaultKind::CorruptPacket { .. } => {} // post-deposit (below)
                #[allow(unreachable_patterns)] // FaultKind is non_exhaustive
                _ => {}
            }
        }
        drop_pair
    }

    /// Apply scripted corrupt faults: force a duplicate packet into the
    /// mailbox slot for `to`. If the slot is occupied (the normal case
    /// — the legitimate packet is there) the occupied-slot invariant
    /// fires right here at the sender; if it was empty, the spurious
    /// packet is caught by the receiver's count expectation.
    #[allow(clippy::disallowed_methods, clippy::disallowed_macros)]
    fn apply_corrupt_faults(&self, label: &'static str, step: usize) {
        let Some(plan) = self.shared.faults.as_deref() else { return };
        let p = self.shared.p;
        for kind in plan.faults_for(self.rank, step) {
            if let FaultKind::CorruptPacket { to } = kind {
                if *to == self.rank || *to >= p {
                    continue;
                }
                let occupied = {
                    let mut slot = lock_robust(&self.shared.slots[self.rank * p + to]);
                    if slot.is_some() {
                        true
                    } else {
                        *slot = Some(vec![C64::ZERO]);
                        false
                    }
                };
                if occupied {
                    self.fail(
                        label,
                        FailureCause::Violation(format!(
                            "duplicate deposit into occupied mailbox slot ({} -> {}) \
                             (corrupted packet)",
                            self.rank, to
                        )),
                    );
                }
            }
        }
    }

    /// Bulk-synchronous all-to-all: `outgoing[j]` is the packet for
    /// processor `j` (may be empty; `outgoing[rank]` is a local move and
    /// is not charged). Returns `incoming[i]` = packet from processor
    /// `i`. Synchronizes all processors (this is the communication
    /// superstep; `l` is charged once).
    ///
    /// Thin owned-value wrapper over [`Ctx::exchange_swap`]; steady-state
    /// callers (e.g. [`crate::fftu::Worker`]) hold the buffer vector
    /// across supersteps and call `exchange_swap` directly, which keeps
    /// the hot path allocation-free.
    pub fn exchange(&mut self, label: &'static str, mut outgoing: Vec<Vec<C64>>) -> Vec<Vec<C64>> {
        self.exchange_swap(label, &mut outgoing);
        outgoing
    }

    /// [`Ctx::exchange`] with compiled receive-count expectations:
    /// `expected_in[i]` is the number of words sender `i` must deliver
    /// (0 = no packet). A missing, short, or oversized packet aborts the
    /// session with a typed violation instead of flowing downstream.
    /// Used by [`crate::bsp::redistribute`], whose `RedistPlan` knows
    /// every pair's packet size at plan time.
    pub fn exchange_checked(
        &mut self,
        label: &'static str,
        mut outgoing: Vec<Vec<C64>>,
        expected_in: &[usize],
    ) -> Vec<Vec<C64>> {
        self.exchange_swap_inner(label, &mut outgoing, Expect::PerSender(expected_in));
        outgoing
    }

    /// Allocation-free all-to-all: on entry `bufs[j]` is the packet for
    /// processor `j`; on return `bufs[i]` is the packet *from* processor
    /// `i`. Buffers move through the mailbox by pointer swap — the heap
    /// allocation behind each `Vec` migrates to the receiving rank and is
    /// recycled as that rank's next outgoing buffer, so a steady-state
    /// exchange performs zero heap allocations.
    ///
    /// Lock discipline: the self packet never touches the mailbox
    /// (`bufs[rank]` stays in place), and **empty packets skip the slot
    /// lock entirely** — the receiver interprets an undisturbed slot as
    /// an empty packet. The ledger's `h` is computed from packet lengths
    /// exactly as before (empty packets contribute zero words), so cost
    /// accounting is bit-identical to the locking-everything variant.
    pub fn exchange_swap(&mut self, label: &'static str, bufs: &mut [Vec<C64>]) {
        self.exchange_swap_inner(label, bufs, Expect::None);
    }

    /// [`Ctx::exchange_swap`] with compiled per-sender receive counts:
    /// the allocation-free sibling of [`Ctx::exchange_checked`].
    /// `expected_in[i]` is the number of words sender `i` must deliver
    /// (0 = no packet). The group-cyclic ladder uses this — each ladder
    /// stage exchanges only within a rank's team, so most slots are
    /// empty by design and a uniform expectation cannot express the
    /// schedule.
    pub fn exchange_swap_checked(
        &mut self,
        label: &'static str,
        bufs: &mut [Vec<C64>],
        expected_in: &[usize],
    ) {
        self.exchange_swap_inner(label, bufs, Expect::PerSender(expected_in));
    }

    /// [`Ctx::exchange_swap`] with a uniform receive-count expectation:
    /// every non-self packet must carry exactly `words` words (FFTU's
    /// Eq. 2.12 packets — the compiled `packet_len` of the plan). A
    /// missing or mis-sized packet aborts the session.
    pub fn exchange_swap_uniform(
        &mut self,
        label: &'static str,
        bufs: &mut [Vec<C64>],
        words: usize,
    ) {
        self.exchange_swap_inner(label, bufs, Expect::Uniform(words));
    }

    /// Split-phase all-to-all, phase 1: deposit this rank's packets into
    /// the mailbox and return immediately — **without** waiting at the
    /// superstep barrier. The packets are "in flight" until the matching
    /// [`Ctx::exchange_finish`]; between the two calls this rank may run
    /// arbitrary *local* computation (the pipelined batch drivers run
    /// the next entry's superstep-0 FFTs here), but no other
    /// communication superstep may start while an exchange is in flight
    /// (the mailbox slots are single-entry).
    ///
    /// Fault injection (pre-deposit panic/delay/drop/truncate and
    /// post-deposit corruption) fires here, at start time, because this
    /// is when the packets physically move; the receive-side length
    /// checks that *detect* those faults fire at `finish`. Ledger
    /// accounting is deferred entirely to `finish`, so a
    /// start-immediately-finish pair is bit-identical to the blocking
    /// [`Ctx::exchange_swap_uniform`] — which is in fact implemented as
    /// exactly that pair.
    pub fn exchange_start(&mut self, label: &'static str, bufs: &mut [Vec<C64>]) {
        let p = self.shared.p;
        assert_eq!(bufs.len(), p, "exchange needs one packet per processor");
        assert!(
            self.pending.is_none(),
            "exchange_start('{label}') while a split-phase exchange is already in flight \
             (missing exchange_finish)"
        );
        let step = self.comm_step;
        self.comm_step += 1;
        if self.shared.faults.is_some() {
            self.apply_pre_faults(label, step, bufs, None);
        }
        let out_words: usize = bufs
            .iter()
            .enumerate()
            .filter(|(j, v)| *j != self.rank && !v.is_empty())
            .map(|(_, v)| v.len())
            .sum();
        // Deposit packets (skip self and empty slots — no lock taken).
        // The occupied-slot check is always on (promoted from a
        // debug_assert): a dirty slot means the previous superstep's
        // drain discipline was violated, and continuing would silently
        // cross packets between supersteps.
        for j in 0..p {
            if j == self.rank || bufs[j].is_empty() {
                continue;
            }
            let occupied = {
                let mut slot = lock_robust(&self.shared.slots[self.rank * p + j]);
                if slot.is_some() {
                    true
                } else {
                    *slot = Some(std::mem::take(&mut bufs[j]));
                    false
                }
            };
            if occupied {
                self.fail(
                    label,
                    FailureCause::Violation(format!(
                        "mailbox slot ({} -> {j}) reused before drain",
                        self.rank
                    )),
                );
            }
        }
        if self.shared.faults.is_some() {
            self.apply_corrupt_faults(label, step);
        }
        self.pending = Some(PendingExchange { label, out_words });
    }

    /// Split-phase all-to-all, phase 2: wait at the superstep barrier,
    /// collect the packets addressed to this rank into `bufs`, and
    /// charge the ledger. Every non-self packet must carry exactly
    /// `words` words (the plan's compiled `packet_len`), as in
    /// [`Ctx::exchange_swap_uniform`]. Must be preceded by a matching
    /// [`Ctx::exchange_start`] on the same `bufs`.
    pub fn exchange_finish(&mut self, bufs: &mut [Vec<C64>], words: usize) {
        self.exchange_finish_inner(bufs, Expect::Uniform(words));
    }

    fn exchange_finish_inner(&mut self, bufs: &mut [Vec<C64>], expect: Expect) {
        let p = self.shared.p;
        let pending = self
            .pending
            .take()
            .expect("exchange_finish without a matching exchange_start");
        let label = pending.label;
        // The communication superstep opens on the ledger here — after
        // any overlapped computation superstep has closed its charges —
        // so the per-superstep ledger stream is identical to the
        // blocking exchange's.
        self.ledger.begin(SuperstepKind::Communication, label);
        self.sync_wait(label);
        // Collect packets addressed to us. A slot left `None` means the
        // sender's packet was empty (it skipped the deposit lock) —
        // unless the compiled schedule says it should not have been.
        let mut in_words = 0usize;
        for (i, buf) in bufs.iter_mut().enumerate() {
            if i == self.rank {
                continue;
            }
            let got = lock_robust(&self.shared.slots[i * p + self.rank]).take();
            let got_words = got.as_ref().map_or(0, Vec::len);
            if let Some(want) = expect.of(i) {
                if got_words != want {
                    self.shared.record_failure(
                        self.rank,
                        label,
                        FailureCause::Violation(format!(
                            "expected {want}-word packet from processor {i}, got {got_words} \
                             (dropped, truncated, or spurious)"
                        )),
                    );
                    self.shared.barrier.abort();
                    abort_unwind();
                }
            }
            match got {
                Some(packet) => {
                    in_words += packet.len();
                    *buf = packet;
                }
                None => buf.clear(),
            }
        }
        // Second barrier: nobody may start depositing the next
        // exchange's packets until every slot has been drained.
        self.sync_wait(label);
        let mem_words: usize = bufs.iter().map(|v| v.len()).sum();
        self.ledger.charge_words(pending.out_words, in_words);
        // Pack + unpack both traverse the full local volume.
        self.ledger.charge_mem_words(2 * mem_words);
    }

    /// Blocking all-to-all = split-phase start immediately followed by
    /// finish. Implementing it this way (rather than as a parallel code
    /// path) is what makes the pipelined engine's ledger charges
    /// bit-identical to the sequential oracle's *by construction*.
    fn exchange_swap_inner(&mut self, label: &'static str, bufs: &mut [Vec<C64>], expect: Expect) {
        self.exchange_start(label, bufs);
        self.exchange_finish_inner(bufs, expect);
    }

    /// Ledger-charged pairwise swap: this processor's `buf` trades
    /// places with `partner`'s `buf` (the rank handed to *its*
    /// `pairwise_exchange` call must be this rank — pairings are
    /// symmetric, like the conjugate pairing `s <-> -s mod p` the
    /// r2c untangle and the cyclic<->zig-zag conversions use).
    ///
    /// This is a full communication superstep: **every** processor must
    /// call it in the same superstep (self-paired ranks pass their own
    /// rank; their buffer is untouched and they only synchronize). Like
    /// [`Ctx::exchange_swap`], buffers move through the mailbox by
    /// pointer swap, so a steady-state pairwise exchange performs zero
    /// heap allocations. The ledger charges `buf.len()` words out and
    /// the partner's length in (0 for self-paired ranks), plus the
    /// pack/unpack memory traffic, exactly as the all-to-all does.
    ///
    /// Always-on detection: a missing partner packet (asymmetric pairing
    /// or a dropped delivery) and an asymmetric packet length (pairwise
    /// swaps are length-symmetric — the FlowConservation invariant the
    /// static verifier checks) abort the session with a typed violation
    /// instead of panicking into a peer deadlock.
    pub fn pairwise_exchange(&mut self, label: &'static str, partner: usize, buf: &mut Vec<C64>) {
        let p = self.shared.p;
        assert!(partner < p, "pairwise_exchange: partner {partner} out of range for p = {p}");
        self.ledger.begin(SuperstepKind::Communication, label);
        let step = self.comm_step;
        self.comm_step += 1;
        let drop_deposit = if self.shared.faults.is_some() {
            self.apply_pre_faults(label, step, std::slice::from_mut(buf), Some(partner))
        } else {
            false
        };
        if partner == self.rank {
            // Self-paired: synchronize with the others, move nothing.
            self.sync_wait(label);
            self.sync_wait(label);
            self.ledger.charge_words(0, 0);
            self.ledger.charge_mem_words(2 * buf.len());
            return;
        }
        let out_words = buf.len();
        if !drop_deposit {
            let occupied = {
                let mut slot = lock_robust(&self.shared.slots[self.rank * p + partner]);
                if slot.is_some() {
                    true
                } else {
                    *slot = Some(std::mem::take(buf));
                    false
                }
            };
            if occupied {
                self.fail(
                    label,
                    FailureCause::Violation(format!(
                        "mailbox slot ({} -> {partner}) reused before drain",
                        self.rank
                    )),
                );
            }
        }
        if self.shared.faults.is_some() {
            self.apply_corrupt_faults(label, step);
        }
        self.sync_wait(label);
        let incoming = lock_robust(&self.shared.slots[partner * p + self.rank]).take();
        let Some(incoming) = incoming else {
            self.fail(
                label,
                FailureCause::Violation(format!(
                    "partner {partner} deposited nothing (asymmetric pairing or dropped packet)"
                )),
            );
        };
        if incoming.len() != out_words {
            self.fail(
                label,
                FailureCause::Violation(format!(
                    "pairwise packet from partner {partner} has {} words, expected {out_words} \
                     (pairwise swaps are length-symmetric)",
                    incoming.len()
                )),
            );
        }
        *buf = incoming;
        // Second barrier, as in exchange_swap: nobody may deposit the
        // next superstep's packets until every slot has been drained.
        self.sync_wait(label);
        self.ledger.charge_words(out_words, buf.len());
        self.ledger.charge_mem_words(2 * buf.len());
    }

    /// Barrier-only synchronization (used by timing harnesses to align
    /// processors before starting a measured region). Routed through the
    /// cancellable barrier under the session deadline, so a stalled
    /// measurement rank times out with a typed failure instead of
    /// wedging `measure_warm` — previously this was a bare
    /// `Barrier::wait` with no abort or deadline. Not a ledger
    /// superstep: alignment syncs are a measurement aid, not part of the
    /// BSP cost (failures here are attributed to the label
    /// `"barrier-sync"`).
    pub fn barrier(&self) {
        self.sync_wait("barrier-sync");
    }
}

impl std::fmt::Debug for Ctx<'_> {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("Ctx")
            .field("rank", &self.rank)
            .field("nprocs", &self.shared.p)
            .finish_non_exhaustive()
    }
}

/// Result of an SPMD run: per-processor outputs plus the folded ledger.
pub struct SpmdOutcome<T> {
    pub outputs: Vec<T>,
    pub report: CostReport,
}

impl<T> std::fmt::Debug for SpmdOutcome<T> {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("SpmdOutcome")
            .field("procs", &self.outputs.len())
            .field("report", &self.report)
            .finish_non_exhaustive()
    }
}

/// Stringify a caught panic payload.
fn payload_message(payload: &(dyn std::any::Any + Send)) -> String {
    payload
        .downcast_ref::<String>()
        .map(|s| s.as_str())
        .or_else(|| payload.downcast_ref::<&str>().copied())
        .unwrap_or("<non-string panic>")
        .to_string()
}

/// Run `f` on `p` virtual processors and gather outputs by rank.
///
/// Panicking wrapper over [`try_run_spmd`]: a failed session panics with
/// **every** failed rank and its superstep label (the registry is in
/// detection order, so the first-listed rank is the actual first
/// fault, not merely the lowest-numbered joining thread).
pub fn run_spmd<T, F>(p: usize, f: F) -> SpmdOutcome<T>
where
    T: Send,
    F: Fn(&mut Ctx) -> T + Sync,
{
    match try_run_spmd(p, f) {
        Ok(outcome) => outcome,
        Err(failure) => panic!("{failure}"),
    }
}

/// [`try_run_spmd_with`] under [`SpmdOptions::default`] (generous
/// deadline, no fault injection).
pub fn try_run_spmd<T, F>(p: usize, f: F) -> Result<SpmdOutcome<T>, BspFailure>
where
    T: Send,
    F: Fn(&mut Ctx) -> T + Sync,
{
    try_run_spmd_with(p, SpmdOptions::default(), f)
}

/// Run `f` on `p` virtual processors; a panic, protocol violation, or
/// deadline timeout in any rank aborts the whole session and surfaces as
/// a typed [`BspFailure`] (failing ranks, superstep labels, causes) —
/// peers are woken from the cancellable barrier and unwound, never
/// deadlocked, and each unwinding rank drains its mailbox row.
// Session setup, not the steady state: the mailbox slots, result slots,
// and failure registry are built once per SPMD run, before any
// superstep.
#[allow(clippy::disallowed_methods)]
pub fn try_run_spmd_with<T, F>(p: usize, opts: SpmdOptions, f: F) -> Result<SpmdOutcome<T>, BspFailure>
where
    T: Send,
    F: Fn(&mut Ctx) -> T + Sync,
{
    assert!(p >= 1);
    let shared = Shared {
        p,
        slots: (0..p * p).map(|_| Mutex::new(None)).collect(),
        barrier: CancellableBarrier::new(p),
        failures: Mutex::new(Vec::new()),
        deadline: opts.deadline,
        faults: opts.faults,
        pipeline: opts.pipeline.max(1),
    };
    let mut results: Vec<Option<(T, ProcLedger)>> = (0..p).map(|_| None).collect();
    std::thread::scope(|scope| {
        for (rank, slot) in results.iter_mut().enumerate() {
            let shared = &shared;
            let f = &f;
            scope.spawn(move || {
                let mut ctx = Ctx {
                    rank,
                    shared,
                    comm_step: 0,
                    pending: None,
                    ledger: ProcLedger::new(),
                };
                match catch_unwind(AssertUnwindSafe(|| f(&mut ctx))) {
                    Ok(out) => *slot = Some((out, ctx.ledger)),
                    Err(payload) => {
                        if payload.downcast_ref::<SessionAborted>().is_none() {
                            // A genuine panic in the closure (assertion,
                            // injected fault, arithmetic, ...): record it
                            // before aborting so the registry is never
                            // empty when peers wake.
                            shared.record_failure(
                                rank,
                                ctx.ledger.current_label(),
                                FailureCause::Panic(payload_message(payload.as_ref())),
                            );
                            shared.barrier.abort();
                        }
                        // Drain this rank's mailbox row so the aborted
                        // session ends with an empty mailbox.
                        for j in 0..p {
                            let _ = lock_robust(&shared.slots[rank * p + j]).take();
                        }
                    }
                }
            });
        }
    });
    let failures = std::mem::take(&mut *lock_robust(&shared.failures));
    if !failures.is_empty() {
        return Err(BspFailure { failures });
    }
    let mut outputs = Vec::with_capacity(p);
    let mut ledgers = Vec::with_capacity(p);
    for r in results {
        let (out, ledger) = r.expect("processor produced no result");
        outputs.push(out);
        ledgers.push(ledger);
    }
    Ok(SpmdOutcome { outputs, report: CostReport::from_procs(&ledgers) })
}

/// Loom model checking of the protocols the static lints cannot see
/// inside: the mailbox pointer-swap handshake, the arena session
/// try-lock, and the cancellable barrier's abort path. `loom::model`
/// runs each closure under EVERY permitted thread interleaving (CI's
/// `loom` job: `RUSTFLAGS="--cfg loom" cargo test --lib loom_`). The
/// models mirror `exchange_swap` / `pairwise_exchange` at p = 2 —
/// deposit under the slot lock, barrier, take under the slot lock,
/// barrier — the `ScratchArena` / `ExecArena` try-lock fallback, and
/// the abort handshake a panicking rank performs.
#[cfg(all(loom, test))]
// Model-checking fixtures, not the steady state: loom explores the
// interleavings of tiny allocated packets.
#[allow(clippy::disallowed_methods, clippy::disallowed_macros)]
mod loom_model {
    use loom::sync::atomic::{AtomicUsize, Ordering};
    use loom::sync::Arc;
    use loom::thread;

    use super::sync::Mutex;
    use super::{BarrierWaitError, CancellableBarrier};

    /// The two-barrier mailbox swap at p = 2: every interleaving must
    /// deliver exactly the partner's packet, never observe an occupied
    /// slot at deposit time, and leave both slots drained. (Barrier
    /// waits go through the cancellable barrier exactly as the runtime's
    /// do; no abort occurs, so every wait must return `Ok`.)
    #[test]
    fn loom_mailbox_swap_is_race_free() {
        loom::model(|| {
            let p = 2usize;
            let slots: Arc<Vec<Mutex<Option<Vec<usize>>>>> =
                Arc::new((0..p * p).map(|_| Mutex::new(None)).collect());
            let barrier = Arc::new(CancellableBarrier::new(p));
            let handles: Vec<_> = (0..p)
                .map(|rank| {
                    let slots = Arc::clone(&slots);
                    let barrier = Arc::clone(&barrier);
                    thread::spawn(move || {
                        let partner = 1 - rank;
                        // Deposit: the slot must be free (the invariant
                        // the second barrier of the previous superstep
                        // guarantees; round 0 starts clean).
                        {
                            let mut slot = slots[rank * p + partner].lock().unwrap();
                            assert!(slot.is_none(), "slot reused before drain");
                            *slot = Some(vec![rank]);
                        }
                        barrier.wait(None).unwrap();
                        // Collect: the partner's packet must be there.
                        let packet = slots[partner * p + rank]
                            .lock()
                            .unwrap()
                            .take()
                            .expect("partner deposited nothing");
                        assert_eq!(packet, vec![partner]);
                        barrier.wait(None).unwrap();
                        // Next round's deposit into the same slot — only
                        // sound because of the second barrier above.
                        {
                            let mut slot = slots[rank * p + partner].lock().unwrap();
                            assert!(slot.is_none(), "round 1 slot not drained");
                            *slot = Some(vec![10 + rank]);
                        }
                        barrier.wait(None).unwrap();
                        let packet = slots[partner * p + rank]
                            .lock()
                            .unwrap()
                            .take()
                            .expect("round 1 packet missing");
                        assert_eq!(packet, vec![10 + partner]);
                        barrier.wait(None).unwrap();
                    })
                })
                .collect();
            for h in handles {
                h.join().unwrap();
            }
        });
    }

    /// The cancellable barrier's abort path: one rank aborts (as the
    /// unwind handler of a panicking rank does) while the other is at —
    /// or heading to — the barrier. Every interleaving must release the
    /// waiter with `Err(Aborted)`; no interleaving may leave it parked
    /// (the deadlock the old `std::sync::Barrier` suffered) or let the
    /// rendezvous spuriously complete.
    #[test]
    fn loom_cancellable_barrier_abort_releases_waiters() {
        loom::model(|| {
            let barrier = Arc::new(CancellableBarrier::new(2));
            let waiter = {
                let barrier = Arc::clone(&barrier);
                thread::spawn(move || barrier.wait(None))
            };
            // The "panicking" rank never arrives; it aborts instead.
            barrier.abort();
            assert_eq!(waiter.join().unwrap(), Err(BarrierWaitError::Aborted));
            // The session stays dead: late arrivals bail immediately.
            assert_eq!(barrier.wait(None), Err(BarrierWaitError::Aborted));
        });
    }

    /// The arena session discipline: two drivers race `try_lock` on one
    /// session mutex; the loser falls back instead of blocking. Every
    /// interleaving must uphold mutual exclusion of the session body and
    /// both threads must always finish (no interleaving blocks).
    #[test]
    fn loom_session_try_lock_fallback() {
        loom::model(|| {
            let session: Arc<Mutex<()>> = Arc::new(Mutex::new(()));
            let active = Arc::new(AtomicUsize::new(0));
            let handles: Vec<_> = (0..2)
                .map(|_| {
                    let session = Arc::clone(&session);
                    let active = Arc::clone(&active);
                    thread::spawn(move || {
                        if let Ok(_guard) = session.try_lock() {
                            // Holder path: we must be alone in here.
                            let before = active.fetch_add(1, Ordering::SeqCst);
                            assert_eq!(before, 0, "two session holders at once");
                            active.fetch_sub(1, Ordering::SeqCst);
                            true
                        } else {
                            // Loser path: transient scratch, no waiting.
                            false
                        }
                    })
                })
                .collect();
            let acquired = handles
                .into_iter()
                .fold(0usize, |acc, h| acc + usize::from(h.join().unwrap()));
            // At least one driver always wins the race.
            assert!(acquired >= 1, "the try-lock must admit a holder");
        });
    }
}

#[cfg(all(test, not(loom)))]
// Test fixtures allocate freely; the allocation audit targets the
// steady-state exchange paths above, not the assertions around them.
#[allow(clippy::disallowed_methods, clippy::disallowed_macros)]
mod tests {
    use super::*;

    #[test]
    fn exchange_routes_packets() {
        let p = 4;
        let outcome = run_spmd(p, |ctx| {
            let s = ctx.rank();
            // Send [s, j] to processor j.
            let outgoing: Vec<Vec<C64>> = (0..p)
                .map(|j| vec![C64::new(s as f64, j as f64)])
                .collect();
            let incoming = ctx.exchange("test", outgoing);
            // Expect packet from i to be [i, s].
            for (i, packet) in incoming.iter().enumerate() {
                assert_eq!(packet.len(), 1);
                assert_eq!(packet[0], C64::new(i as f64, s as f64));
            }
            s
        });
        assert_eq!(outcome.outputs, vec![0, 1, 2, 3]);
        assert_eq!(outcome.report.comm_supersteps(), 1);
        // Each proc sends p-1 = 3 words to others.
        assert_eq!(outcome.report.supersteps[0].h_max, 3);
    }

    #[test]
    fn repeated_exchanges_do_not_cross_supersteps() {
        let p = 3;
        let outcome = run_spmd(p, |ctx| {
            let s = ctx.rank() as f64;
            let mut acc = C64::ZERO;
            for round in 0..5 {
                let outgoing: Vec<Vec<C64>> =
                    (0..p).map(|_| vec![C64::new(s, round as f64)]).collect();
                let incoming = ctx.exchange("round", outgoing);
                for packet in &incoming {
                    assert_eq!(packet[0].im, round as f64, "superstep bleed");
                    acc += packet[0];
                }
            }
            acc
        });
        assert_eq!(outcome.report.comm_supersteps(), 5);
        // Sum over rounds and senders of C64(sender, round).
        let want_re = (0.0 + 1.0 + 2.0) * 5.0;
        for out in outcome.outputs {
            assert_eq!(out.re, want_re);
        }
    }

    #[test]
    fn exchange_swap_recycles_buffers_and_skips_empty_packets() {
        let p = 3;
        let outcome = run_spmd(p, |ctx| {
            let s = ctx.rank();
            // Rank s sends to j only when s + j is even; empty otherwise.
            // Empty packets never take a mailbox lock, and the receiver
            // sees them as empty buffers.
            let mut bufs: Vec<Vec<C64>> = (0..p)
                .map(|j| {
                    if (s + j) % 2 == 0 {
                        vec![C64::new(s as f64, j as f64); 2]
                    } else {
                        Vec::new()
                    }
                })
                .collect();
            ctx.exchange_swap("swap", &mut bufs);
            for (i, pkt) in bufs.iter().enumerate() {
                if (i + s) % 2 == 0 {
                    assert_eq!(pkt.len(), 2, "rank {s} from {i}");
                    assert_eq!(pkt[0], C64::new(i as f64, s as f64));
                } else {
                    assert!(pkt.is_empty(), "rank {s} from {i}");
                }
            }
            s
        });
        // Only the 0 <-> 2 pair exchanges (2 words each way); rank 1 is
        // idle. The ledger must charge exactly the nonempty traffic.
        assert_eq!(outcome.report.supersteps[0].h_max, 2);
        assert_eq!(outcome.report.supersteps[0].words_total, 4);
    }

    #[test]
    fn exchange_swap_steady_state_reuses_capacity() {
        // Across repeated exchanges the same buffer allocations circulate
        // between ranks: every buffer a rank holds after round k has the
        // capacity some rank allocated before round 1.
        let p = 2;
        run_spmd(p, |ctx| {
            let mut bufs: Vec<Vec<C64>> = (0..p).map(|_| vec![C64::ONE; 8]).collect();
            for round in 0..4 {
                for b in bufs.iter_mut() {
                    b.clear();
                    b.extend(std::iter::repeat(C64::new(round as f64, 0.0)).take(8));
                    assert_eq!(b.capacity(), 8, "buffer grew unexpectedly");
                }
                ctx.exchange_swap("steady", &mut bufs);
                for b in &bufs {
                    assert_eq!(b.len(), 8);
                    assert_eq!(b.capacity(), 8);
                }
            }
        });
    }

    #[test]
    fn pairwise_exchange_swaps_with_partner_and_charges_the_pair() {
        // p = 5, partner map s <-> -s mod 5: 0 self, 1<->4, 2<->3.
        let p = 5;
        let outcome = run_spmd(p, |ctx| {
            let s = ctx.rank();
            let partner = (p - s) % p;
            let mut buf = vec![C64::new(s as f64, 0.0); 3];
            ctx.pairwise_exchange("pair", partner, &mut buf);
            if partner == s {
                assert_eq!(buf[0], C64::new(s as f64, 0.0), "self-pair must keep its buffer");
            } else {
                assert_eq!(buf.len(), 3);
                assert_eq!(buf[0], C64::new(partner as f64, 0.0), "rank {s}");
            }
            s
        });
        assert_eq!(outcome.report.comm_supersteps(), 1);
        // Each non-self rank sends and receives 3 words.
        assert_eq!(outcome.report.supersteps[0].h_max, 3);
        assert_eq!(outcome.report.supersteps[0].words_total, 4 * 3);
    }

    #[test]
    fn pairwise_exchange_recycles_capacity_across_rounds() {
        let p = 2;
        run_spmd(p, |ctx| {
            let s = ctx.rank();
            let partner = 1 - s;
            let mut buf = vec![C64::ONE; 4];
            for round in 0..4 {
                buf.clear();
                buf.extend(std::iter::repeat(C64::new(round as f64, s as f64)).take(4));
                assert_eq!(buf.capacity(), 4, "buffer grew unexpectedly");
                ctx.pairwise_exchange("pair", partner, &mut buf);
                assert_eq!(buf.len(), 4);
                assert_eq!(buf.capacity(), 4);
                assert_eq!(buf[0], C64::new(round as f64, partner as f64));
            }
        });
    }

    #[test]
    fn pairwise_exchange_interleaves_with_alltoall_supersteps() {
        // The trig pipeline mixes the all-to-all and pairwise supersteps
        // in one session; slot discipline must hold across both.
        let p = 3;
        let outcome = run_spmd(p, |ctx| {
            let s = ctx.rank();
            let outgoing: Vec<Vec<C64>> =
                (0..p).map(|j| vec![C64::new(s as f64, j as f64)]).collect();
            let incoming = ctx.exchange("a2a", outgoing);
            assert_eq!(incoming[(s + 1) % p][0].im, s as f64);
            let partner = (p - s) % p;
            let mut buf = vec![C64::new(10.0 + s as f64, 0.0); 2];
            ctx.pairwise_exchange("pair", partner, &mut buf);
            assert_eq!(buf[0].re, 10.0 + partner as f64);
        });
        assert_eq!(outcome.report.comm_supersteps(), 2);
    }

    #[test]
    fn ledger_collects_computation_flops() {
        let outcome = run_spmd(2, |ctx| {
            ctx.begin_comp("work");
            ctx.charge_flops(10.0 * (ctx.rank() + 1) as f64);
            let out: Vec<Vec<C64>> = vec![vec![]; 2];
            ctx.exchange("sync", out);
        });
        assert_eq!(outcome.report.supersteps.len(), 2);
        assert_eq!(outcome.report.supersteps[0].w_max, 20.0);
    }

    #[test]
    fn single_processor_degenerate_case() {
        let outcome = run_spmd(1, |ctx| {
            let incoming = ctx.exchange("self", vec![vec![C64::ONE]]);
            incoming[0][0]
        });
        assert_eq!(outcome.outputs[0], C64::ONE);
        // Self-sends are not charged as communication words.
        assert_eq!(outcome.report.supersteps[0].h_max, 0);
    }

    #[test]
    #[should_panic(expected = "BSP processor 1")]
    fn panics_propagate_with_rank() {
        // Before the cancellable barrier, rank 0 reaching an exchange
        // here would deadlock forever (std::sync::Barrier has no abort);
        // now the abort releases it and the panic carries rank 1's
        // failure. Rank 0 deliberately enters the exchange to prove it.
        run_spmd(2, |ctx| {
            if ctx.rank() == 1 {
                panic!("boom");
            }
            let mut bufs: Vec<Vec<C64>> = vec![vec![C64::ONE]; 2];
            ctx.exchange_swap("post-panic", &mut bufs);
        });
    }

    #[test]
    fn abort_wakes_waiters_and_reports_the_failing_rank() {
        let err = try_run_spmd(3, |ctx| {
            if ctx.rank() == 2 {
                ctx.begin_comp("doomed");
                panic!("kaput");
            }
            // Ranks 0 and 1 are parked at the exchange barrier when the
            // abort lands; they must wake and unwind, not deadlock.
            let mut bufs: Vec<Vec<C64>> = vec![vec![C64::ONE]; 3];
            ctx.exchange_swap("survivors", &mut bufs);
        })
        .unwrap_err();
        assert_eq!(err.failures.len(), 1, "victims must not be recorded: {err}");
        assert_eq!(err.first().rank, 2);
        assert_eq!(err.first().superstep, "doomed");
        assert!(matches!(err.first().cause, FailureCause::Panic(ref m) if m == "kaput"));
    }

    #[test]
    fn all_failed_ranks_are_reported() {
        // Two independent panics: both must land in the registry (the
        // old join loop re-panicked on the lowest rank in join order,
        // hiding the other).
        let err = try_run_spmd(4, |ctx| {
            if ctx.rank() == 1 || ctx.rank() == 3 {
                panic!("rank {} down", ctx.rank());
            }
            let mut bufs: Vec<Vec<C64>> = vec![vec![C64::ONE]; 4];
            ctx.exchange_swap("peers", &mut bufs);
        })
        .unwrap_err();
        let mut ranks: Vec<usize> = err.failures.iter().map(|f| f.rank).collect();
        ranks.sort_unstable();
        assert_eq!(ranks, vec![1, 3]);
        let msg = err.to_string();
        assert!(msg.contains("BSP processor 1") && msg.contains("BSP processor 3"), "{msg}");
    }

    #[test]
    fn superstep_deadline_converts_stall_into_timeout() {
        let opts = SpmdOptions::default().with_deadline(Duration::from_millis(50));
        let err = try_run_spmd_with(2, opts, |ctx| {
            if ctx.rank() == 1 {
                // Stalled rank: never panics, just arrives very late.
                std::thread::sleep(Duration::from_millis(400));
            }
            ctx.barrier();
        })
        .unwrap_err();
        assert!(err.timed_out(), "{err}");
        assert_eq!(err.first().rank, 0, "the waiting rank detects the stall");
        assert_eq!(err.first().superstep, "barrier-sync");
    }

    #[test]
    fn injected_panic_fault_aborts_with_typed_failure() {
        let faults = FaultPlan::new().with(0, 1, FaultKind::Panic);
        let err = try_run_spmd_with(2, SpmdOptions::default().inject(faults), |ctx| {
            for _ in 0..3 {
                let mut bufs: Vec<Vec<C64>> = vec![vec![C64::ONE; 2]; 2];
                ctx.exchange_swap("rounds", &mut bufs);
            }
        })
        .unwrap_err();
        assert_eq!(err.first().rank, 0);
        assert_eq!(err.first().superstep, "rounds");
        assert!(matches!(err.first().cause, FailureCause::Panic(_)));
    }

    #[test]
    fn injected_delay_fault_times_out_the_peers() {
        let faults = FaultPlan::new().with(1, 0, FaultKind::Delay(Duration::from_millis(400)));
        let opts = SpmdOptions::default().with_deadline(Duration::from_millis(60)).inject(faults);
        let err = try_run_spmd_with(2, opts, |ctx| {
            let mut bufs: Vec<Vec<C64>> = vec![vec![C64::ONE]; 2];
            ctx.exchange_swap("delayed", &mut bufs);
        })
        .unwrap_err();
        assert!(err.timed_out(), "{err}");
        assert_eq!(err.first().rank, 0, "the healthy rank reports the timeout");
        assert_eq!(err.first().superstep, "delayed");
    }

    #[test]
    fn dropped_packet_is_caught_by_count_expectation() {
        let faults = FaultPlan::new().with(1, 0, FaultKind::DropPacket { to: 0 });
        let err = try_run_spmd_with(2, SpmdOptions::default().inject(faults), |ctx| {
            let mut bufs: Vec<Vec<C64>> = vec![vec![C64::ONE; 3]; 2];
            ctx.exchange_swap_uniform("checked", &mut bufs, 3);
        })
        .unwrap_err();
        assert_eq!(err.first().rank, 0, "the receiver detects the drop");
        assert!(
            matches!(err.first().cause, FailureCause::Violation(ref m) if m.contains("expected 3-word")),
            "{err}"
        );
    }

    #[test]
    fn truncated_packet_is_caught_by_count_expectation() {
        let faults = FaultPlan::new().with(1, 0, FaultKind::TruncatePacket { to: 0, keep: 1 });
        let err = try_run_spmd_with(2, SpmdOptions::default().inject(faults), |ctx| {
            let mut bufs: Vec<Vec<C64>> = vec![vec![C64::ONE; 3]; 2];
            ctx.exchange_swap_uniform("checked", &mut bufs, 3);
        })
        .unwrap_err();
        assert_eq!(err.first().rank, 0);
        assert!(
            matches!(err.first().cause, FailureCause::Violation(ref m) if m.contains("got 1")),
            "{err}"
        );
    }

    #[test]
    fn corrupt_packet_trips_the_occupied_slot_invariant() {
        let faults = FaultPlan::new().with(1, 0, FaultKind::CorruptPacket { to: 0 });
        let err = try_run_spmd_with(2, SpmdOptions::default().inject(faults), |ctx| {
            let mut bufs: Vec<Vec<C64>> = vec![vec![C64::ONE; 2]; 2];
            ctx.exchange_swap_uniform("checked", &mut bufs, 2);
        })
        .unwrap_err();
        assert_eq!(err.first().rank, 1, "the corrupting rank trips its own deposit invariant");
        assert!(
            matches!(err.first().cause, FailureCause::Violation(ref m) if m.contains("occupied")),
            "{err}"
        );
    }

    #[test]
    fn asymmetric_pairwise_pairing_aborts_instead_of_deadlocking() {
        // Rank 1 wrongly self-pairs, so rank 0's partner slot stays
        // empty: previously an `expect` panic that deadlocked rank 1 at
        // the second barrier; now a typed violation for the session.
        let err = try_run_spmd(2, |ctx| {
            let partner = 1; // rank 0 pairs with 1; rank 1 wrongly self-pairs
            let mut buf = vec![C64::ONE; 2];
            ctx.pairwise_exchange("mispair", partner, &mut buf);
        })
        .unwrap_err();
        assert_eq!(err.first().rank, 0);
        assert!(
            matches!(err.first().cause, FailureCause::Violation(ref m) if m.contains("deposited nothing")),
            "{err}"
        );
    }

    #[test]
    fn armed_but_unmatched_fault_plan_leaves_execution_untouched() {
        // Fault plane armed with a site no superstep reaches: results
        // and ledger must be identical to a fault-free run.
        let faults = FaultPlan::new().with(0, 99, FaultKind::Panic);
        let outcome = try_run_spmd_with(2, SpmdOptions::default().inject(faults), |ctx| {
            let s = ctx.rank();
            let outgoing: Vec<Vec<C64>> = (0..2).map(|j| vec![C64::new(s as f64, j as f64)]).collect();
            let incoming = ctx.exchange("clean", outgoing);
            incoming[1 - s][0]
        })
        .unwrap();
        assert_eq!(outcome.outputs[0], C64::new(1.0, 0.0));
        assert_eq!(outcome.outputs[1], C64::new(0.0, 1.0));
        assert_eq!(outcome.report.comm_supersteps(), 1);
    }
}
