//! The BSP multiprocessor runtime.
//!
//! Runs an SPMD closure on `p` virtual processors (one OS thread each),
//! provides the bulk-synchronous all-to-all exchange the algorithms need
//! (the realization of superstep-1 `Put`s in Alg. 2.2/2.3 — all Puts of a
//! superstep between a pair of processors form one packet), and records
//! the per-processor cost ledger.
//!
//! This is the substitute for MPI + Snellius: the exchange moves real
//! data between real threads through shared memory, with the same
//! structure (packets, h-relations, barrier semantics) the paper's MPI
//! implementation has over Infiniband. Wall-clock timings at small p are
//! measured on this runtime; paper-scale p is extrapolated through
//! [`crate::costmodel`] from the exact ledgers recorded here.
//!
//! Under `--cfg loom` the private `sync` shim swaps the standard-library
//! synchronization primitives for [loom](https://docs.rs/loom)'s
//! model-checked versions, and the `loom_model` tests at the bottom of
//! this file explore EVERY interleaving of the mailbox pointer-swap
//! protocol and the arena session try-lock (CI's `loom` job). The
//! dependency-free companion checker lives in
//! [`crate::analysis::interleave`].

// This file is one of the three allocation-audited hot modules (see
// clippy.toml): the steady-state paths (`exchange_swap`,
// `pairwise_exchange`) must stay free of allocation-prone calls; the
// session-setup and test code that legitimately allocates carries
// explicit `#[allow]`s with justifications.
#![deny(clippy::disallowed_methods, clippy::disallowed_macros)]

use sync::{Barrier, Mutex};

use super::ledger::{CostReport, ProcLedger, SuperstepKind};
use crate::fft::C64;

/// Synchronization primitives behind the runtime: the standard library
/// by default, loom's model-checked doubles under `--cfg loom` (loom
/// ships no `Barrier`, so the loom side carries a condvar-based one
/// with the same `new`/`wait` surface).
mod sync {
    #[cfg(not(loom))]
    pub(crate) use std::sync::{Barrier, Mutex};

    #[cfg(loom)]
    pub(crate) use loom::sync::Mutex;

    #[cfg(loom)]
    pub(crate) struct Barrier {
        state: loom::sync::Mutex<BarrierState>,
        cvar: loom::sync::Condvar,
        n: usize,
    }

    #[cfg(loom)]
    struct BarrierState {
        count: usize,
        generation: usize,
    }

    #[cfg(loom)]
    impl Barrier {
        pub(crate) fn new(n: usize) -> Self {
            Barrier {
                state: loom::sync::Mutex::new(BarrierState { count: 0, generation: 0 }),
                cvar: loom::sync::Condvar::new(),
                n,
            }
        }

        /// Same semantics as `std::sync::Barrier::wait` (minus the
        /// leader token, which the runtime never uses): the `n`-th
        /// arrival resets the count and wakes every waiter; earlier
        /// arrivals sleep until the generation advances.
        pub(crate) fn wait(&self) {
            let mut st = self.state.lock().unwrap();
            let generation = st.generation;
            st.count += 1;
            if st.count == self.n {
                st.count = 0;
                st.generation += 1;
                self.cvar.notify_all();
            } else {
                while st.generation == generation {
                    st = self.cvar.wait(st).unwrap();
                }
            }
        }
    }
}

/// Shared state for one SPMD run.
struct Shared {
    p: usize,
    /// Mailbox slot (sender, receiver) -> packet in flight.
    slots: Vec<Mutex<Option<Vec<C64>>>>,
    barrier: Barrier,
}

/// Per-processor execution context handed to the SPMD closure.
pub struct Ctx<'a> {
    rank: usize,
    shared: &'a Shared,
    pub ledger: ProcLedger,
}

impl<'a> Ctx<'a> {
    /// This processor's rank `s in [p]`.
    #[inline]
    pub fn rank(&self) -> usize {
        self.rank
    }

    /// Number of processors.
    #[inline]
    pub fn nprocs(&self) -> usize {
        self.shared.p
    }

    /// Begin a computation superstep (cost-accounting only; computation
    /// supersteps need no synchronization with one-sided communication,
    /// which is why the paper charges `l` only for communication).
    pub fn begin_comp(&mut self, label: &'static str) {
        self.ledger.begin(SuperstepKind::Computation, label);
    }

    /// Charge flops to the current computation superstep.
    pub fn charge_flops(&mut self, flops: f64) {
        self.ledger.charge_flops(flops);
    }

    /// Bulk-synchronous all-to-all: `outgoing[j]` is the packet for
    /// processor `j` (may be empty; `outgoing[rank]` is a local move and
    /// is not charged). Returns `incoming[i]` = packet from processor
    /// `i`. Synchronizes all processors (this is the communication
    /// superstep; `l` is charged once).
    ///
    /// Thin owned-value wrapper over [`Ctx::exchange_swap`]; steady-state
    /// callers (e.g. [`crate::fftu::Worker`]) hold the buffer vector
    /// across supersteps and call `exchange_swap` directly, which keeps
    /// the hot path allocation-free.
    pub fn exchange(&mut self, label: &'static str, mut outgoing: Vec<Vec<C64>>) -> Vec<Vec<C64>> {
        self.exchange_swap(label, &mut outgoing);
        outgoing
    }

    /// Allocation-free all-to-all: on entry `bufs[j]` is the packet for
    /// processor `j`; on return `bufs[i]` is the packet *from* processor
    /// `i`. Buffers move through the mailbox by pointer swap — the heap
    /// allocation behind each `Vec` migrates to the receiving rank and is
    /// recycled as that rank's next outgoing buffer, so a steady-state
    /// exchange performs zero heap allocations.
    ///
    /// Lock discipline: the self packet never touches the mailbox
    /// (`bufs[rank]` stays in place), and **empty packets skip the slot
    /// lock entirely** — the receiver interprets an undisturbed slot as
    /// an empty packet. The ledger's `h` is computed from packet lengths
    /// exactly as before (empty packets contribute zero words), so cost
    /// accounting is bit-identical to the locking-everything variant.
    pub fn exchange_swap(&mut self, label: &'static str, bufs: &mut [Vec<C64>]) {
        let p = self.shared.p;
        assert_eq!(bufs.len(), p, "exchange needs one packet per processor");
        self.ledger.begin(SuperstepKind::Communication, label);
        let out_words: usize = bufs
            .iter()
            .enumerate()
            .filter(|(j, v)| *j != self.rank && !v.is_empty())
            .map(|(_, v)| v.len())
            .sum();
        // Deposit packets (skip self and empty slots — no lock taken).
        for (j, packet) in bufs.iter_mut().enumerate() {
            if j == self.rank || packet.is_empty() {
                continue;
            }
            let mut slot = self.shared.slots[self.rank * p + j].lock().unwrap();
            debug_assert!(slot.is_none(), "mailbox slot reused before drain");
            *slot = Some(std::mem::take(packet));
        }
        self.shared.barrier.wait();
        // Collect packets addressed to us. A slot left `None` means the
        // sender's packet was empty (it skipped the deposit lock).
        let mut in_words = 0usize;
        for (i, buf) in bufs.iter_mut().enumerate() {
            if i == self.rank {
                continue;
            }
            match self.shared.slots[i * p + self.rank].lock().unwrap().take() {
                Some(packet) => {
                    in_words += packet.len();
                    *buf = packet;
                }
                None => buf.clear(),
            }
        }
        // Second barrier: nobody may start depositing the next
        // exchange's packets until every slot has been drained.
        self.shared.barrier.wait();
        let mem_words: usize = bufs.iter().map(|v| v.len()).sum();
        self.ledger.charge_words(out_words, in_words);
        // Pack + unpack both traverse the full local volume.
        self.ledger.charge_mem_words(2 * mem_words);
    }

    /// Ledger-charged pairwise swap: this processor's `buf` trades
    /// places with `partner`'s `buf` (the rank handed to *its*
    /// `pairwise_exchange` call must be this rank — pairings are
    /// symmetric, like the conjugate pairing `s <-> -s mod p` the
    /// r2c untangle and the cyclic<->zig-zag conversions use).
    ///
    /// This is a full communication superstep: **every** processor must
    /// call it in the same superstep (self-paired ranks pass their own
    /// rank; their buffer is untouched and they only synchronize). Like
    /// [`Ctx::exchange_swap`], buffers move through the mailbox by
    /// pointer swap, so a steady-state pairwise exchange performs zero
    /// heap allocations. The ledger charges `buf.len()` words out and
    /// the partner's length in (0 for self-paired ranks), plus the
    /// pack/unpack memory traffic, exactly as the all-to-all does.
    pub fn pairwise_exchange(&mut self, label: &'static str, partner: usize, buf: &mut Vec<C64>) {
        let p = self.shared.p;
        assert!(partner < p, "pairwise_exchange: partner {partner} out of range for p = {p}");
        self.ledger.begin(SuperstepKind::Communication, label);
        if partner == self.rank {
            // Self-paired: synchronize with the others, move nothing.
            self.shared.barrier.wait();
            self.shared.barrier.wait();
            self.ledger.charge_words(0, 0);
            self.ledger.charge_mem_words(2 * buf.len());
            return;
        }
        let out_words = buf.len();
        {
            let mut slot = self.shared.slots[self.rank * p + partner].lock().unwrap();
            debug_assert!(slot.is_none(), "mailbox slot reused before drain");
            *slot = Some(std::mem::take(buf));
        }
        self.shared.barrier.wait();
        let incoming = self.shared.slots[partner * p + self.rank]
            .lock()
            .unwrap()
            .take()
            .expect("pairwise_exchange: partner deposited nothing (asymmetric pairing?)");
        *buf = incoming;
        // Second barrier, as in exchange_swap: nobody may deposit the
        // next superstep's packets until every slot has been drained.
        self.shared.barrier.wait();
        self.ledger.charge_words(out_words, buf.len());
        self.ledger.charge_mem_words(2 * buf.len());
    }

    /// Barrier-only synchronization (used by timing harnesses to align
    /// processors before starting a measured region).
    pub fn barrier(&self) {
        self.shared.barrier.wait();
    }
}

impl std::fmt::Debug for Ctx<'_> {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("Ctx")
            .field("rank", &self.rank)
            .field("nprocs", &self.shared.p)
            .finish_non_exhaustive()
    }
}

/// Result of an SPMD run: per-processor outputs plus the folded ledger.
pub struct SpmdOutcome<T> {
    pub outputs: Vec<T>,
    pub report: CostReport,
}

impl<T> std::fmt::Debug for SpmdOutcome<T> {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("SpmdOutcome")
            .field("procs", &self.outputs.len())
            .field("report", &self.report)
            .finish_non_exhaustive()
    }
}

/// Run `f` on `p` virtual processors and gather outputs by rank.
///
/// Panics in any processor propagate (with rank context) after all
/// threads are joined, so a failing assertion inside an algorithm shows
/// up as a test failure rather than a deadlock.
// Session setup, not the steady state: the mailbox slots, result slots,
// and join handles are built once per SPMD run, before any superstep.
#[allow(clippy::disallowed_methods)]
pub fn run_spmd<T, F>(p: usize, f: F) -> SpmdOutcome<T>
where
    T: Send,
    F: Fn(&mut Ctx) -> T + Sync,
{
    assert!(p >= 1);
    let shared = Shared {
        p,
        slots: (0..p * p).map(|_| Mutex::new(None)).collect(),
        barrier: Barrier::new(p),
    };
    let mut results: Vec<Option<(T, ProcLedger)>> = (0..p).map(|_| None).collect();
    std::thread::scope(|scope| {
        let mut handles = Vec::with_capacity(p);
        for (rank, slot) in results.iter_mut().enumerate() {
            let shared = &shared;
            let f = &f;
            handles.push(scope.spawn(move || {
                let mut ctx = Ctx { rank, shared, ledger: ProcLedger::new() };
                let out = f(&mut ctx);
                *slot = Some((out, ctx.ledger));
            }));
        }
        for (rank, h) in handles.into_iter().enumerate() {
            if let Err(e) = h.join() {
                let msg = e
                    .downcast_ref::<String>()
                    .map(|s| s.as_str())
                    .or_else(|| e.downcast_ref::<&str>().copied())
                    .unwrap_or("<non-string panic>");
                panic!("BSP processor {rank} panicked: {msg}");
            }
        }
    });
    let mut outputs = Vec::with_capacity(p);
    let mut ledgers = Vec::with_capacity(p);
    for r in results {
        let (out, ledger) = r.expect("processor produced no result");
        outputs.push(out);
        ledgers.push(ledger);
    }
    SpmdOutcome { outputs, report: CostReport::from_procs(&ledgers) }
}

/// Loom model checking of the two protocols the static lints cannot
/// see inside: the mailbox pointer-swap handshake and the arena session
/// try-lock. `loom::model` runs each closure under EVERY permitted
/// thread interleaving (CI's `loom` job: `RUSTFLAGS="--cfg loom"
/// cargo test --lib loom_`). The models mirror `exchange_swap` /
/// `pairwise_exchange` at p = 2 — deposit under the slot lock, barrier,
/// take under the slot lock, barrier — and the `ScratchArena` /
/// `ExecArena` try-lock fallback.
#[cfg(all(loom, test))]
// Model-checking fixtures, not the steady state: loom explores the
// interleavings of tiny allocated packets.
#[allow(clippy::disallowed_methods, clippy::disallowed_macros)]
mod loom_model {
    use loom::sync::atomic::{AtomicUsize, Ordering};
    use loom::sync::Arc;
    use loom::thread;

    use super::sync::{Barrier, Mutex};

    /// The two-barrier mailbox swap at p = 2: every interleaving must
    /// deliver exactly the partner's packet, never observe an occupied
    /// slot at deposit time, and leave both slots drained.
    #[test]
    fn loom_mailbox_swap_is_race_free() {
        loom::model(|| {
            let p = 2usize;
            let slots: Arc<Vec<Mutex<Option<Vec<usize>>>>> =
                Arc::new((0..p * p).map(|_| Mutex::new(None)).collect());
            let barrier = Arc::new(Barrier::new(p));
            let handles: Vec<_> = (0..p)
                .map(|rank| {
                    let slots = Arc::clone(&slots);
                    let barrier = Arc::clone(&barrier);
                    thread::spawn(move || {
                        let partner = 1 - rank;
                        // Deposit: the slot must be free (the invariant
                        // the second barrier of the previous superstep
                        // guarantees; round 0 starts clean).
                        {
                            let mut slot = slots[rank * p + partner].lock().unwrap();
                            assert!(slot.is_none(), "slot reused before drain");
                            *slot = Some(vec![rank]);
                        }
                        barrier.wait();
                        // Collect: the partner's packet must be there.
                        let packet = slots[partner * p + rank]
                            .lock()
                            .unwrap()
                            .take()
                            .expect("partner deposited nothing");
                        assert_eq!(packet, vec![partner]);
                        barrier.wait();
                        // Next round's deposit into the same slot — only
                        // sound because of the second barrier above.
                        {
                            let mut slot = slots[rank * p + partner].lock().unwrap();
                            assert!(slot.is_none(), "round 1 slot not drained");
                            *slot = Some(vec![10 + rank]);
                        }
                        barrier.wait();
                        let packet = slots[partner * p + rank]
                            .lock()
                            .unwrap()
                            .take()
                            .expect("round 1 packet missing");
                        assert_eq!(packet, vec![10 + partner]);
                        barrier.wait();
                    })
                })
                .collect();
            for h in handles {
                h.join().unwrap();
            }
        });
    }

    /// The arena session discipline: two drivers race `try_lock` on one
    /// session mutex; the loser falls back instead of blocking. Every
    /// interleaving must uphold mutual exclusion of the session body and
    /// both threads must always finish (no interleaving blocks).
    #[test]
    fn loom_session_try_lock_fallback() {
        loom::model(|| {
            let session: Arc<Mutex<()>> = Arc::new(Mutex::new(()));
            let active = Arc::new(AtomicUsize::new(0));
            let handles: Vec<_> = (0..2)
                .map(|_| {
                    let session = Arc::clone(&session);
                    let active = Arc::clone(&active);
                    thread::spawn(move || {
                        if let Ok(_guard) = session.try_lock() {
                            // Holder path: we must be alone in here.
                            let before = active.fetch_add(1, Ordering::SeqCst);
                            assert_eq!(before, 0, "two session holders at once");
                            active.fetch_sub(1, Ordering::SeqCst);
                            true
                        } else {
                            // Loser path: transient scratch, no waiting.
                            false
                        }
                    })
                })
                .collect();
            let acquired = handles
                .into_iter()
                .fold(0usize, |acc, h| acc + usize::from(h.join().unwrap()));
            // At least one driver always wins the race.
            assert!(acquired >= 1, "the try-lock must admit a holder");
        });
    }
}

#[cfg(all(test, not(loom)))]
// Test fixtures allocate freely; the allocation audit targets the
// steady-state exchange paths above, not the assertions around them.
#[allow(clippy::disallowed_methods, clippy::disallowed_macros)]
mod tests {
    use super::*;

    #[test]
    fn exchange_routes_packets() {
        let p = 4;
        let outcome = run_spmd(p, |ctx| {
            let s = ctx.rank();
            // Send [s, j] to processor j.
            let outgoing: Vec<Vec<C64>> = (0..p)
                .map(|j| vec![C64::new(s as f64, j as f64)])
                .collect();
            let incoming = ctx.exchange("test", outgoing);
            // Expect packet from i to be [i, s].
            for (i, packet) in incoming.iter().enumerate() {
                assert_eq!(packet.len(), 1);
                assert_eq!(packet[0], C64::new(i as f64, s as f64));
            }
            s
        });
        assert_eq!(outcome.outputs, vec![0, 1, 2, 3]);
        assert_eq!(outcome.report.comm_supersteps(), 1);
        // Each proc sends p-1 = 3 words to others.
        assert_eq!(outcome.report.supersteps[0].h_max, 3);
    }

    #[test]
    fn repeated_exchanges_do_not_cross_supersteps() {
        let p = 3;
        let outcome = run_spmd(p, |ctx| {
            let s = ctx.rank() as f64;
            let mut acc = C64::ZERO;
            for round in 0..5 {
                let outgoing: Vec<Vec<C64>> =
                    (0..p).map(|_| vec![C64::new(s, round as f64)]).collect();
                let incoming = ctx.exchange("round", outgoing);
                for packet in &incoming {
                    assert_eq!(packet[0].im, round as f64, "superstep bleed");
                    acc += packet[0];
                }
            }
            acc
        });
        assert_eq!(outcome.report.comm_supersteps(), 5);
        // Sum over rounds and senders of C64(sender, round).
        let want_re = (0.0 + 1.0 + 2.0) * 5.0;
        for out in outcome.outputs {
            assert_eq!(out.re, want_re);
        }
    }

    #[test]
    fn exchange_swap_recycles_buffers_and_skips_empty_packets() {
        let p = 3;
        let outcome = run_spmd(p, |ctx| {
            let s = ctx.rank();
            // Rank s sends to j only when s + j is even; empty otherwise.
            // Empty packets never take a mailbox lock, and the receiver
            // sees them as empty buffers.
            let mut bufs: Vec<Vec<C64>> = (0..p)
                .map(|j| {
                    if (s + j) % 2 == 0 {
                        vec![C64::new(s as f64, j as f64); 2]
                    } else {
                        Vec::new()
                    }
                })
                .collect();
            ctx.exchange_swap("swap", &mut bufs);
            for (i, pkt) in bufs.iter().enumerate() {
                if (i + s) % 2 == 0 {
                    assert_eq!(pkt.len(), 2, "rank {s} from {i}");
                    assert_eq!(pkt[0], C64::new(i as f64, s as f64));
                } else {
                    assert!(pkt.is_empty(), "rank {s} from {i}");
                }
            }
            s
        });
        // Only the 0 <-> 2 pair exchanges (2 words each way); rank 1 is
        // idle. The ledger must charge exactly the nonempty traffic.
        assert_eq!(outcome.report.supersteps[0].h_max, 2);
        assert_eq!(outcome.report.supersteps[0].words_total, 4);
    }

    #[test]
    fn exchange_swap_steady_state_reuses_capacity() {
        // Across repeated exchanges the same buffer allocations circulate
        // between ranks: every buffer a rank holds after round k has the
        // capacity some rank allocated before round 1.
        let p = 2;
        run_spmd(p, |ctx| {
            let mut bufs: Vec<Vec<C64>> = (0..p).map(|_| vec![C64::ONE; 8]).collect();
            for round in 0..4 {
                for b in bufs.iter_mut() {
                    b.clear();
                    b.extend(std::iter::repeat(C64::new(round as f64, 0.0)).take(8));
                    assert_eq!(b.capacity(), 8, "buffer grew unexpectedly");
                }
                ctx.exchange_swap("steady", &mut bufs);
                for b in &bufs {
                    assert_eq!(b.len(), 8);
                    assert_eq!(b.capacity(), 8);
                }
            }
        });
    }

    #[test]
    fn pairwise_exchange_swaps_with_partner_and_charges_the_pair() {
        // p = 5, partner map s <-> -s mod 5: 0 self, 1<->4, 2<->3.
        let p = 5;
        let outcome = run_spmd(p, |ctx| {
            let s = ctx.rank();
            let partner = (p - s) % p;
            let mut buf = vec![C64::new(s as f64, 0.0); 3];
            ctx.pairwise_exchange("pair", partner, &mut buf);
            if partner == s {
                assert_eq!(buf[0], C64::new(s as f64, 0.0), "self-pair must keep its buffer");
            } else {
                assert_eq!(buf.len(), 3);
                assert_eq!(buf[0], C64::new(partner as f64, 0.0), "rank {s}");
            }
            s
        });
        assert_eq!(outcome.report.comm_supersteps(), 1);
        // Each non-self rank sends and receives 3 words.
        assert_eq!(outcome.report.supersteps[0].h_max, 3);
        assert_eq!(outcome.report.supersteps[0].words_total, 4 * 3);
    }

    #[test]
    fn pairwise_exchange_recycles_capacity_across_rounds() {
        let p = 2;
        run_spmd(p, |ctx| {
            let s = ctx.rank();
            let partner = 1 - s;
            let mut buf = vec![C64::ONE; 4];
            for round in 0..4 {
                buf.clear();
                buf.extend(std::iter::repeat(C64::new(round as f64, s as f64)).take(4));
                assert_eq!(buf.capacity(), 4, "buffer grew unexpectedly");
                ctx.pairwise_exchange("pair", partner, &mut buf);
                assert_eq!(buf.len(), 4);
                assert_eq!(buf.capacity(), 4);
                assert_eq!(buf[0], C64::new(round as f64, partner as f64));
            }
        });
    }

    #[test]
    fn pairwise_exchange_interleaves_with_alltoall_supersteps() {
        // The trig pipeline mixes the all-to-all and pairwise supersteps
        // in one session; slot discipline must hold across both.
        let p = 3;
        let outcome = run_spmd(p, |ctx| {
            let s = ctx.rank();
            let outgoing: Vec<Vec<C64>> =
                (0..p).map(|j| vec![C64::new(s as f64, j as f64)]).collect();
            let incoming = ctx.exchange("a2a", outgoing);
            assert_eq!(incoming[(s + 1) % p][0].im, s as f64);
            let partner = (p - s) % p;
            let mut buf = vec![C64::new(10.0 + s as f64, 0.0); 2];
            ctx.pairwise_exchange("pair", partner, &mut buf);
            assert_eq!(buf[0].re, 10.0 + partner as f64);
        });
        assert_eq!(outcome.report.comm_supersteps(), 2);
    }

    #[test]
    fn ledger_collects_computation_flops() {
        let outcome = run_spmd(2, |ctx| {
            ctx.begin_comp("work");
            ctx.charge_flops(10.0 * (ctx.rank() + 1) as f64);
            let out: Vec<Vec<C64>> = vec![vec![]; 2];
            ctx.exchange("sync", out);
        });
        assert_eq!(outcome.report.supersteps.len(), 2);
        assert_eq!(outcome.report.supersteps[0].w_max, 20.0);
    }

    #[test]
    fn single_processor_degenerate_case() {
        let outcome = run_spmd(1, |ctx| {
            let incoming = ctx.exchange("self", vec![vec![C64::ONE]]);
            incoming[0][0]
        });
        assert_eq!(outcome.outputs[0], C64::ONE);
        // Self-sends are not charged as communication words.
        assert_eq!(outcome.report.supersteps[0].h_max, 0);
    }

    #[test]
    #[should_panic(expected = "BSP processor")]
    fn panics_propagate_with_rank() {
        run_spmd(2, |ctx| {
            if ctx.rank() == 1 {
                panic!("boom");
            }
            // Other rank must not deadlock on the barrier: panic unwinding
            // poisons the barrier? std Barrier has no poisoning; rank 0
            // would block forever if it reached an exchange. Keep rank 0
            // exchange-free so the test terminates.
        });
    }
}
