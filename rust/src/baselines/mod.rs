//! Comparator algorithms from §1.2, implemented from their published
//! descriptions and validated against the sequential oracle.
//!
//! All baselines run on the same BSP machine and sequential FFT substrate
//! as FFTU, so the comparison isolates *communication structure* — the
//! paper's subject — from kernel quality.
//!
//! | Baseline | Input dist | Comm supersteps (fwd) | p_max |
//! |---|---|---|---|
//! | [`slab`] (parallel FFTW) | slab axis 0 | 1 (+1 if same-dist out) | `min(n_1, N/n_1)` |
//! | [`pencil`] (PFFT, r-dim) | blocks on r axes | `ceil(r/(d-r))` (+1) | see §1.2 |
//! | [`heffte`] (heFFTe) | bricks | pencil pipeline + reshapes | pencil-bound |
//! | [`popovici`] (cyclic d-step) | cyclic | d | `prod sqrt(n_l)` |
//!
//! Each baseline follows the same plan/execute split as FFTU: a
//! `*Plan` struct built once (validation, distribution schedules,
//! compiled redistributions, local FFT plans) and executed many times.
//! The `*_global` free functions are one-shot wrappers kept for tests
//! and scripts; applications and the [`crate::api`] facade reuse plans.

pub mod heffte;
pub mod pencil;
pub mod popovici;
pub mod slab;

pub use heffte::{heffte_global, heffte_pmax, heffte_schedule, HefftePlan};
pub use pencil::{
    pencil_global, pencil_pmax, pencil_r2c_global, pencil_schedule, pfft_best_pmax, PencilPlan,
};
pub use popovici::{popovici_global, popovici_pmax, PopoviciPlan};
pub use slab::{slab_dists, slab_global, slab_pmax, slab_r2c_global, SlabPlan};

/// Whether the transform must end in the distribution it started in
/// ("same", the paper's default comparison) or may end transposed
/// ("different", FFTW_TRANSPOSED_OUT / PFFT_TRANSPOSED_OUT).
#[derive(Clone, Copy, PartialEq, Eq, Hash, Debug)]
pub enum OutputDist {
    Same,
    Different,
}

use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Mutex, MutexGuard, PoisonError};

use crate::bsp::SpmdOptions;
use crate::fft::C64;

/// Per-rank persistent scratch, shared across the execute calls of one
/// baseline plan — the baselines' share of the PR's arena design, so
/// FFTU's zero-allocation steady state is compared against baselines
/// that also stopped reallocating their scratch every call (fairness of
/// the wall-clock comparison). Leases grow on first use and then stay.
///
/// Leases are held across BSP barriers, so the arena admits ONE SPMD
/// session at a time: drivers call [`ScratchArena::begin_session`]
/// before `run_spmd` and fall back to transient per-call scratch when
/// another session owns the arena (two interleaved sessions holding
/// each other's rank slots across barriers would cross-deadlock).
pub(crate) struct ScratchArena {
    session: Mutex<()>,
    slots: Vec<Mutex<Vec<C64>>>,
    /// Set after an abnormal session exit; the next `begin_session`
    /// wipes the leases (they regrow lazily) and clears the flag.
    poisoned: AtomicBool,
    /// Session options (deadline, fault injection) for every execute
    /// through this plan's arena.
    exec_opts: Mutex<SpmdOptions>,
}

impl ScratchArena {
    pub(crate) fn new(p: usize) -> Self {
        ScratchArena {
            session: Mutex::new(()),
            slots: (0..p).map(|_| Mutex::new(Vec::new())).collect(),
            poisoned: AtomicBool::new(false),
            exec_opts: Mutex::new(SpmdOptions::default()),
        }
    }

    /// Claim the arena for one SPMD session; `None` means a concurrent
    /// execute owns it and the caller must use transient scratch. A
    /// previous abnormal exit's scratch is wiped here (it regrows on the
    /// next lease), so recovery is transparent.
    pub(crate) fn begin_session(&self) -> Option<MutexGuard<'_, ()>> {
        let guard = match self.session.try_lock() {
            Ok(g) => g,
            Err(std::sync::TryLockError::Poisoned(p)) => p.into_inner(),
            Err(std::sync::TryLockError::WouldBlock) => return None,
        };
        if self.poisoned.swap(false, Ordering::AcqRel) {
            for slot in &self.slots {
                slot.lock().unwrap_or_else(PoisonError::into_inner).clear();
            }
        }
        Some(guard)
    }

    /// Mark the arena unreliable after an abnormal session exit.
    pub(crate) fn poison(&self) {
        self.poisoned.store(true, Ordering::Release);
    }

    /// Set the session options used by subsequent executes.
    pub(crate) fn set_exec_options(&self, opts: SpmdOptions) {
        *self.exec_opts.lock().unwrap_or_else(PoisonError::into_inner) = opts;
    }

    /// The session options subsequent executes will run under.
    pub(crate) fn exec_options(&self) -> SpmdOptions {
        self.exec_opts.lock().unwrap_or_else(PoisonError::into_inner).clone()
    }

    /// Lock rank `rank`'s scratch, growing it to at least `min_len`
    /// (zero-filled) — a no-op after the first execute. Only call while
    /// holding the [`Self::begin_session`] guard. Poison-tolerant: a
    /// panicking rank poisons its slot mutex, but `begin_session` has
    /// already cleared the contents.
    pub(crate) fn lease(&self, rank: usize, min_len: usize) -> MutexGuard<'_, Vec<C64>> {
        let mut guard = self.slots[rank].lock().unwrap_or_else(PoisonError::into_inner);
        if guard.len() < min_len {
            let len = guard.len();
            guard.reserve_exact(min_len - len);
            guard.resize(min_len, C64::ZERO);
        }
        guard
    }
}
