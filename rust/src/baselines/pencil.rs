//! PFFT-style r-dimensional decomposition (§1.2).
//!
//! The input is block-distributed over the first `r` axes (a pencil
//! distribution when `r = 2`). The `d - r` remaining axes are local and
//! transformed immediately; then the algorithm performs
//! `ceil(r / (d-r))` redistributions, each making up to `d - r`
//! still-untransformed axes local, until every axis has been transformed.
//! With `OutputDist::Same` a final redistribution restores the input
//! distribution (this is the extra step the paper's Tables 4.1/4.2 charge
//! PFFT for in the "same" columns).
//!
//! Planning (schedule, compiled redistributions, local FFT plans) lives
//! in [`PencilPlan`]; [`pencil_global`] is the one-shot wrapper.

use std::sync::Arc;

use crate::api::FftError;
use super::ScratchArena;
use crate::bsp::{redistribute, try_run_spmd_with, CostReport, Ctx};
use crate::dist::{GridDist, RedistPlan};
use crate::fft::ndfft::transform_axis;
use crate::fft::{C64, Direction, Plan, Planner};

use super::OutputDist;

/// Place `p` processors block-wise on the axes in `allowed` (all other
/// grid entries 1). Greedy: largest prime factors first, each assigned to
/// the allowed axis with the most remaining capacity. Returns `None` if
/// `p` does not fit.
pub(crate) fn fit_grid(shape: &[usize], allowed: &[usize], p: usize) -> Option<Vec<usize>> {
    let d = shape.len();
    let mut grid = vec![1usize; d];
    let mut factors = prime_factors(p);
    factors.sort_unstable_by(|a, b| b.cmp(a));
    for f in factors {
        let mut best: Option<(usize, usize)> = None; // (capacity, axis)
        for &l in allowed {
            let q = grid[l] * f;
            if shape[l] % q == 0 {
                let cap = shape[l] / q;
                if best.map(|(c, _)| cap > c).unwrap_or(true) {
                    best = Some((cap, l));
                }
            }
        }
        let (_, l) = best?;
        grid[l] *= f;
    }
    Some(grid)
}

pub(crate) fn prime_factors(mut n: usize) -> Vec<usize> {
    let mut fs = Vec::new();
    let mut q = 2;
    while q * q <= n {
        while n % q == 0 {
            fs.push(q);
            n /= q;
        }
        q += 1;
    }
    if n > 1 {
        fs.push(n);
    }
    fs
}

/// The paper's p_max for an r-dimensional decomposition (§1.2): with a
/// single redistribution (`r <= d/2`), the best split
/// `max_S min(prod_S, prod_{S^c})`; for `r > d/2` (multiple
/// redistributions) the processors must at some stage sit on the `r`
/// smallest axes, giving the product of the `r` smallest sizes (for
/// d = 3, r = 2 this is the paper's `min(n1n2, n2n3, n1n3) = n2n3`).
pub fn pencil_pmax(shape: &[usize], r: usize) -> usize {
    let d = shape.len();
    assert!(r >= 1 && r < d);
    if 2 * r <= d {
        // Enumerate r-subsets (d is small).
        let mut best = 0;
        let total: usize = shape.iter().product();
        for mask in 0usize..(1 << d) {
            if (mask.count_ones() as usize) != r {
                continue;
            }
            let prod_s: usize = (0..d).filter(|l| mask >> l & 1 == 1).map(|l| shape[l]).product();
            best = best.max(prod_s.min(total / prod_s));
        }
        best
    } else {
        let mut sorted = shape.to_vec();
        sorted.sort_unstable();
        sorted[..r].iter().product()
    }
}

/// Best PFFT p_max over all decomposition ranks `1 <= r < d`.
pub fn pfft_best_pmax(shape: &[usize]) -> usize {
    (1..shape.len()).map(|r| pencil_pmax(shape, r)).max().unwrap()
}

/// The pencil algorithm's full distribution schedule: the input
/// distribution plus one `(distribution, axes-to-transform)` entry per
/// redistribution stage. Shared by the executor and the analytic cost
/// model.
pub fn pencil_schedule(
    shape: &[usize],
    r: usize,
    p: usize,
) -> Result<(GridDist, Vec<(GridDist, Vec<usize>)>), FftError> {
    let d = shape.len();
    if r == 0 || r >= d {
        return Err(FftError::BadDescriptor {
            reason: format!("decomposition rank r={r} must satisfy 1 <= r < d={d}"),
        });
    }
    // Input distribution: p processors block-wise on the first r axes.
    let in_axes: Vec<usize> = (0..r).collect();
    let in_grid = fit_grid(shape, &in_axes, p)
        .ok_or(FftError::NoValidGrid { p, pmax: pencil_pmax(shape, r) })?;
    let dist_in = GridDist::blocks(shape, &in_grid)?;

    // Each stage redistributes so that the next chunk of <= d-r
    // untransformed axes becomes local, with processors allowed on every
    // other axis.
    let mut pending: Vec<usize> = (0..r).collect();
    let mut stages: Vec<(GridDist, Vec<usize>)> = Vec::new();
    while !pending.is_empty() {
        let take = (d - r).min(pending.len());
        let now: Vec<usize> = pending.drain(..take).collect();
        let allowed: Vec<usize> = (0..d).filter(|l| !now.contains(l)).collect();
        let grid = fit_grid(shape, &allowed, p)
            .ok_or(FftError::NoValidGrid { p, pmax: pencil_pmax(shape, r) })?;
        stages.push((GridDist::blocks(shape, &grid)?, now));
    }
    Ok((dist_in, stages))
}

/// Validated, fully planned r-dimensional decomposition pipeline.
pub struct PencilPlan {
    shape: Vec<usize>,
    r: usize,
    p: usize,
    out: OutputDist,
    dist_in: GridDist,
    stages: Vec<(GridDist, Vec<usize>)>,
    redists: Vec<RedistPlan>,
    back: RedistPlan,
    axis_plan: Vec<Arc<Plan>>,
    /// Per-rank scratch persisted across executes (arena reuse, sized
    /// for the largest stage at plan time).
    scratch: ScratchArena,
}

impl std::fmt::Debug for PencilPlan {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("PencilPlan")
            .field("shape", &self.shape)
            .field("r", &self.r)
            .field("p", &self.p)
            .field("out", &self.out)
            .finish_non_exhaustive()
    }
}

impl PencilPlan {
    pub fn new(shape: &[usize], r: usize, p: usize, out: OutputDist) -> Result<Self, FftError> {
        let (dist_in, stages) = pencil_schedule(shape, r, p)?;
        let mut dists: Vec<&GridDist> = vec![&dist_in];
        for (dist, _) in &stages {
            dists.push(dist);
        }
        let mut redists: Vec<RedistPlan> = Vec::new();
        for w in dists.windows(2) {
            redists.push(RedistPlan::new(w[0], w[1])?);
        }
        let back = RedistPlan::new(dists.last().unwrap(), &dist_in)?;
        let planner = Planner::new();
        let axis_plan: Vec<Arc<Plan>> = shape.iter().map(|&n| planner.plan(n)).collect();
        Ok(PencilPlan {
            shape: shape.to_vec(),
            r,
            p,
            out,
            dist_in,
            stages,
            redists,
            back,
            axis_plan,
            scratch: ScratchArena::new(p),
        })
    }

    pub fn num_procs(&self) -> usize {
        self.p
    }

    pub fn input_dist(&self) -> &GridDist {
        &self.dist_in
    }

    /// The compiled per-stage transposes, in execution order (the static
    /// verifier reads their send matrices; no payload is touched).
    pub fn redist_plans(&self) -> &[RedistPlan] {
        &self.redists
    }

    /// The compiled transpose back to the input distribution (executed
    /// only with [`OutputDist::Same`]).
    pub fn back_plan(&self) -> &RedistPlan {
        &self.back
    }

    /// Whether the plan transposes back to the input distribution.
    pub fn output_dist(&self) -> OutputDist {
        self.out
    }

    fn final_dist(&self) -> &GridDist {
        match self.out {
            OutputDist::Different => {
                self.stages.last().map(|(d, _)| d).unwrap_or(&self.dist_in)
            }
            OutputDist::Same => &self.dist_in,
        }
    }

    /// Set the BSP session options (superstep deadline, fault
    /// injection) used by subsequent executes of this plan.
    pub fn set_exec_options(&self, opts: crate::bsp::SpmdOptions) {
        self.scratch.set_exec_options(opts);
    }

    /// Execute on whole (global) arrays; the report covers the batch.
    /// Panics on a BSP session failure — use
    /// [`Self::try_execute_batch_global`] for typed recovery.
    pub fn execute_batch_global(
        &self,
        inputs: &[&[C64]],
        dir: Direction,
    ) -> (Vec<Vec<C64>>, CostReport) {
        self.try_execute_batch_global(inputs, dir)
            .unwrap_or_else(|e| panic!("{e}"))
    }

    /// Execute on whole (global) arrays, surfacing BSP session failures
    /// (injected faults, protocol violations, timeouts) as typed
    /// errors. An abnormal exit poisons the scratch arena; the next
    /// execute rebuilds it transparently.
    pub fn try_execute_batch_global(
        &self,
        inputs: &[&[C64]],
        dir: Direction,
    ) -> Result<(Vec<Vec<C64>>, CostReport), FftError> {
        let d = self.shape.len();
        let locals: Vec<Vec<Vec<C64>>> =
            inputs.iter().map(|g| self.dist_in.scatter(g)).collect();
        // Axes r..d are local in the input distribution and are
        // transformed up front; axes 0..r are covered by the stages.
        let first_axes: Vec<usize> = (self.r..d).collect();
        // Largest scratch any stage needs, known at plan time.
        let max_axis = *self.shape.iter().max().unwrap();
        let scratch_len = self
            .stages
            .iter()
            .map(|(dist, _)| dist.local_len())
            .fold(self.dist_in.local_len().max(4 * max_axis), usize::max);
        // One session per arena; a concurrent execute of this same plan
        // falls back to transient scratch (see ScratchArena).
        let arena_session = self.scratch.begin_session();
        let outcome = try_run_spmd_with(self.p, self.scratch.exec_options(), |ctx: &mut Ctx| {
            let mut scratch_guard;
            let mut owned_scratch;
            let scratch: &mut [C64] = match &arena_session {
                Some(_) => {
                    scratch_guard = self.scratch.lease(ctx.rank(), scratch_len);
                    scratch_guard.as_mut_slice()
                }
                None => {
                    owned_scratch = vec![C64::ZERO; scratch_len];
                    owned_scratch.as_mut_slice()
                }
            };
            let mut outs = Vec::with_capacity(inputs.len());
            for item in &locals {
                let mut local = item[ctx.rank()].clone();
                // Stage 0: transform the initially local axes.
                ctx.begin_comp("pencil-local-axes");
                let lshape = self.dist_in.local_shape();
                for &l in &first_axes {
                    transform_axis(&mut local, lshape, l, &self.axis_plan[l], &mut scratch, dir);
                    ctx.charge_flops(flops_axis(lshape, l));
                }
                // Redistribution stages.
                for (i, (dist, now)) in self.stages.iter().enumerate() {
                    local = redistribute(ctx, &self.redists[i], "pencil-transpose", &local);
                    debug_assert!(scratch.len() >= local.len(), "plan-time scratch bound wrong");
                    ctx.begin_comp("pencil-stage-axes");
                    let lshape = dist.local_shape();
                    for &l in now {
                        transform_axis(&mut local, lshape, l, &self.axis_plan[l], &mut scratch, dir);
                        ctx.charge_flops(flops_axis(lshape, l));
                    }
                }
                outs.push(match self.out {
                    OutputDist::Different => local,
                    OutputDist::Same => {
                        redistribute(ctx, &self.back, "pencil-transpose-back", &local)
                    }
                });
            }
            outs
        })
        .map_err(|failure| {
            self.scratch.poison();
            FftError::from(failure)
        })?;
        Ok((self.final_dist().gather_batch(&outcome.outputs), outcome.report))
    }
}

/// Real-to-complex pencil transform via the packing trick: pack adjacent
/// last-axis pairs, run the r-dimensional decomposition on the half
/// shape `[..., n_d/2]`, untangle into the Hermitian half-spectrum
/// (`[..., n_d/2 + 1]`, unnormalized). The PFFT-style cross-check for
/// the distributed r2c conformance suite.
pub fn pencil_r2c_global(
    shape: &[usize],
    r: usize,
    p: usize,
    real: &[f64],
    out: OutputDist,
) -> Result<(Vec<C64>, CostReport), FftError> {
    use crate::fft::realnd::{half_shape, r2c_drive, validate_even_last_axis};
    validate_even_last_axis(shape)?;
    let plan = PencilPlan::new(&half_shape(shape), r, p, out)?;
    r2c_drive(shape, p, real, |packed| {
        let (mut outs, report) = plan.execute_batch_global(&[packed], Direction::Forward);
        Ok((outs.pop().unwrap(), report))
    })
}

/// One-shot convenience: plan, run once, gather.
pub fn pencil_global(
    shape: &[usize],
    r: usize,
    p: usize,
    global: &[C64],
    dir: Direction,
    out: OutputDist,
) -> Result<(Vec<C64>, CostReport), FftError> {
    let plan = PencilPlan::new(shape, r, p, out)?;
    let (mut outs, report) = plan.execute_batch_global(&[global], dir);
    Ok((outs.pop().unwrap(), report))
}

fn flops_axis(local_shape: &[usize], l: usize) -> f64 {
    let total: usize = local_shape.iter().product();
    let n = local_shape[l];
    if n <= 1 {
        0.0
    } else {
        5.0 * total as f64 * (n as f64).log2()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::fft::{fftn_inplace, rel_l2_error};
    use crate::testing::Rng;

    fn rand_global(n: usize, rng: &mut Rng) -> Vec<C64> {
        (0..n).map(|_| C64::new(rng.f64_signed(), rng.f64_signed())).collect()
    }

    fn check(shape: &[usize], r: usize, p: usize, out: OutputDist, want_comm: usize) {
        let mut rng = Rng::new(0xEC1);
        let n: usize = shape.iter().product();
        let x = rand_global(n, &mut rng);
        let mut want = x.clone();
        fftn_inplace(&mut want, shape, Direction::Forward);
        let (got, report) = pencil_global(shape, r, p, &x, Direction::Forward, out).unwrap();
        let err = rel_l2_error(&got, &want);
        assert!(err < 1e-9, "shape {shape:?} r={r} p={p} {out:?}: err {err}");
        assert_eq!(
            report.comm_supersteps(),
            want_comm,
            "shape {shape:?} r={r} p={p} {out:?}"
        );
    }

    #[test]
    fn pencil_3d_r2_needs_two_transposes() {
        // d=3, r=2: ceil(2/1) = 2 redistributions (+1 for same).
        check(&[8, 8, 8], 2, 4, OutputDist::Different, 2);
        check(&[8, 8, 8], 2, 4, OutputDist::Same, 3);
        check(&[8, 8, 8], 2, 16, OutputDist::Different, 2);
    }

    #[test]
    fn pencil_3d_r1_is_slab_like() {
        check(&[8, 8, 8], 1, 8, OutputDist::Different, 1);
        check(&[8, 8, 8], 1, 8, OutputDist::Same, 2);
    }

    #[test]
    fn pencil_5d_r2_single_redistribution() {
        // d=5, r=2: ceil(2/3) = 1 redistribution.
        check(&[4, 4, 4, 4, 4], 2, 16, OutputDist::Different, 1);
        check(&[4, 4, 4, 4, 4], 2, 16, OutputDist::Same, 2);
    }

    #[test]
    fn pencil_4d_r2() {
        check(&[4, 4, 4, 4], 2, 16, OutputDist::Different, 1);
    }

    #[test]
    fn pmax_matches_paper_formulas() {
        // d=3, r=2, 1024^3: pmax = n2 n3 = 2^20.
        assert_eq!(pencil_pmax(&[1024, 1024, 1024], 2), 1 << 20);
        // d=5, r=2, 64^5: single redistribution, min(64^2, 64^3) = 4096.
        assert_eq!(pencil_pmax(&[64, 64, 64, 64, 64], 2), 4096);
        // d=4 equal sizes, r=2: N^{1/2}.
        assert_eq!(pencil_pmax(&[16, 16, 16, 16], 2), 256);
        // r=1 is the slab bound min(n1, N/n1).
        assert_eq!(pencil_pmax(&[1024, 1024, 1024], 1), 1024);
        assert_eq!(pfft_best_pmax(&[1024, 1024, 1024]), 1 << 20);
    }

    #[test]
    fn pencil_r2c_matches_sequential_rfftn() {
        use crate::fft::realnd::rfftn;
        let mut rng = Rng::new(0xEC3);
        for (shape, r, p) in [(vec![8usize, 8, 8], 2usize, 4usize), (vec![8, 16], 1, 4)] {
            let n: usize = shape.iter().product();
            let x: Vec<f64> = (0..n).map(|_| rng.f64_signed()).collect();
            let want = rfftn(&x, &shape);
            let (got, _) = pencil_r2c_global(&shape, r, p, &x, OutputDist::Same).unwrap();
            let err = rel_l2_error(&got, &want);
            assert!(err < 1e-10, "shape {shape:?} r={r} p={p}: err {err}");
        }
    }

    #[test]
    fn pencil_inverse_roundtrip_via_facade_normalization() {
        use crate::api::{Algorithm, Normalization, Transform};
        let mut rng = Rng::new(0xEC2);
        let shape = [4usize, 4, 4];
        let x = rand_global(64, &mut rng);
        let fwd = Transform::new(&shape).procs(4).plan(Algorithm::pencil(2)).unwrap();
        let y = fwd.execute(&x).unwrap();
        let inv = Transform::new(&shape)
            .procs(4)
            .inverse()
            .normalization(Normalization::ByN)
            .plan(Algorithm::pencil(2))
            .unwrap();
        let z = inv.execute(&y.output).unwrap();
        assert!(crate::fft::max_abs_diff(&z.output, &x) < 1e-9);
    }

    #[test]
    fn pencil_rejects_oversized_p_with_typed_error() {
        let x = vec![C64::ZERO; 4 * 4 * 4];
        // p = 32 cannot sit on two axes of 4x4x4 (max 16).
        assert!(matches!(
            pencil_global(&[4, 4, 4], 2, 32, &x, Direction::Forward, OutputDist::Same),
            Err(FftError::NoValidGrid { p: 32, .. })
        ));
        assert!(matches!(
            pencil_global(&[8, 8], 2, 4, &x[..64], Direction::Forward, OutputDist::Same),
            Err(FftError::BadDescriptor { .. })
        ));
    }
}
