//! Parallel-FFTW-style slab algorithm (§1.2).
//!
//! Start in a slab distribution along axis 0: axes `1..d` are local, so
//! transform them sequentially. Then perform one global transpose to a
//! distribution in which axis 0 is local — a slab along axis 1 when
//! `p <= n_2`, otherwise a block distribution over as many of the later
//! axes as needed (FFTW's `r > 2` case) — and transform axis 0. With
//! `OutputDist::Same` a second transpose returns to the input slab.
//!
//! Planning (distribution choice, redistribution routing, local FFT
//! plans) lives in [`SlabPlan`]; [`slab_global`] is the one-shot
//! convenience wrapper. Long-lived callers (and the [`crate::api`]
//! facade's plan cache) build a `SlabPlan` once and execute it many
//! times.

use std::sync::Arc;

use crate::api::FftError;
use crate::bsp::{redistribute, try_run_spmd_with, CostReport, Ctx};
use crate::dist::{GridDist, RedistPlan};
use crate::fft::ndfft::transform_axis;
use crate::fft::{C64, Direction, Plan, Planner};

use super::{OutputDist, ScratchArena};

/// Maximum processors for the slab algorithm: `min(n_1, N/n_1)` (§1.2).
pub fn slab_pmax(shape: &[usize]) -> usize {
    let n1 = shape[0];
    let rest: usize = shape[1..].iter().product();
    n1.min(rest)
}

/// Choose the post-transpose distribution: axis 0 local, `p` processors
/// spread block-wise over axes `1..d` greedily (FFTW uses axis 1 alone
/// when possible; we generalize exactly as the paper describes for the
/// `8 x 4 x 2` example, ending in a pencil or higher-rank block grid).
fn second_dist(shape: &[usize], p: usize) -> Result<GridDist, FftError> {
    let d = shape.len();
    let mut grid = vec![1usize; d];
    let mut rem = p;
    for l in 1..d {
        if rem == 1 {
            break;
        }
        let take = gcd_pow(rem, shape[l]);
        grid[l] = take;
        rem /= take;
    }
    if rem != 1 {
        return Err(FftError::NoValidGrid { p, pmax: slab_pmax(shape) });
    }
    GridDist::blocks(shape, &grid)
}

/// Largest divisor of `cap`'s headroom: greatest `g` dividing both `rem`
/// (a processor count) and `n` (an axis length).
fn gcd_pow(rem: usize, n: usize) -> usize {
    let mut g = 1;
    for c in 1..=rem.min(n) {
        if rem % c == 0 && n % c == 0 {
            g = c;
        }
    }
    g
}

/// The two distributions the slab algorithm moves between: the input
/// slab along axis 0 and the post-transpose distribution with axis 0
/// local. Shared by the executor and the analytic cost model so the
/// paper-scale predictions use exactly the executed schedule.
pub fn slab_dists(shape: &[usize], p: usize) -> Result<(GridDist, GridDist), FftError> {
    let d = shape.len();
    if d < 2 {
        return Err(FftError::BadDescriptor { reason: "slab algorithm needs d >= 2".into() });
    }
    if p > slab_pmax(shape) {
        return Err(FftError::TooManyProcs { algo: "slab", p, pmax: slab_pmax(shape) });
    }
    if shape[0] % p != 0 {
        return Err(FftError::AxisConstraint { axis: 0, n: shape[0], p, requires: "p | n_1" });
    }
    Ok((GridDist::slab(shape, 0, p)?, second_dist(shape, p)?))
}

/// Validated, fully planned slab pipeline for one (shape, p, output)
/// triple: distributions, compiled transposes, and local FFT plans.
pub struct SlabPlan {
    shape: Vec<usize>,
    p: usize,
    out: OutputDist,
    dist_in: GridDist,
    dist_mid: GridDist,
    transpose: RedistPlan,
    back: RedistPlan,
    plans_in: Vec<Arc<Plan>>,
    plan_axis0: Arc<Plan>,
    local_in_shape: Vec<usize>,
    local_mid_shape: Vec<usize>,
    /// Per-rank scratch persisted across executes (arena reuse — the
    /// baselines match FFTU's no-per-call-scratch discipline so timing
    /// comparisons stay fair).
    scratch: ScratchArena,
}

impl std::fmt::Debug for SlabPlan {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("SlabPlan")
            .field("shape", &self.shape)
            .field("p", &self.p)
            .field("out", &self.out)
            .finish_non_exhaustive()
    }
}

impl SlabPlan {
    pub fn new(shape: &[usize], p: usize, out: OutputDist) -> Result<Self, FftError> {
        let d = shape.len();
        let (dist_in, dist_mid) = slab_dists(shape, p)?;
        let transpose = RedistPlan::new(&dist_in, &dist_mid)?;
        let back = RedistPlan::new(&dist_mid, &dist_in)?;
        let planner = Planner::new();
        let plans_in: Vec<Arc<Plan>> = (1..d).map(|l| planner.plan(shape[l])).collect();
        let plan_axis0 = planner.plan(shape[0]);
        let local_in_shape = dist_in.local_shape().to_vec();
        let local_mid_shape = dist_mid.local_shape().to_vec();
        Ok(SlabPlan {
            shape: shape.to_vec(),
            p,
            out,
            dist_in,
            dist_mid,
            transpose,
            back,
            plans_in,
            plan_axis0,
            local_in_shape,
            local_mid_shape,
            scratch: ScratchArena::new(p),
        })
    }

    pub fn num_procs(&self) -> usize {
        self.p
    }

    /// The distribution the input (and, with `OutputDist::Same`, the
    /// output) lives in.
    pub fn input_dist(&self) -> &GridDist {
        &self.dist_in
    }

    /// The compiled slab -> mid transpose (the static verifier reads its
    /// send matrix; no payload is touched).
    pub fn transpose_plan(&self) -> &RedistPlan {
        &self.transpose
    }

    /// The compiled mid -> slab transpose back (executed only with
    /// [`OutputDist::Same`]).
    pub fn back_plan(&self) -> &RedistPlan {
        &self.back
    }

    /// Whether the plan transposes back to the input distribution.
    pub fn output_dist(&self) -> OutputDist {
        self.out
    }

    /// Session options (superstep deadline, fault injection) for every
    /// subsequent execute of this plan.
    pub fn set_exec_options(&self, opts: crate::bsp::SpmdOptions) {
        self.scratch.set_exec_options(opts);
    }

    /// Execute the planned pipeline on whole (global) arrays: scatter,
    /// run the BSP program once per batch item with persistent scratch,
    /// gather. The report covers the entire batch. Panicking wrapper
    /// over [`SlabPlan::try_execute_batch_global`].
    pub fn execute_batch_global(
        &self,
        inputs: &[&[C64]],
        dir: Direction,
    ) -> (Vec<Vec<C64>>, CostReport) {
        self.try_execute_batch_global(inputs, dir).unwrap_or_else(|e| panic!("{e}"))
    }

    /// Fallible execute: a rank panic, protocol violation, or superstep
    /// timeout in the BSP session surfaces as
    /// [`FftError::RankFailure`] / [`FftError::Timeout`]; the scratch
    /// arena is poisoned and transparently rebuilt on the next execute.
    pub fn try_execute_batch_global(
        &self,
        inputs: &[&[C64]],
        dir: Direction,
    ) -> Result<(Vec<Vec<C64>>, CostReport), FftError> {
        let d = self.shape.len();
        let locals: Vec<Vec<Vec<C64>>> =
            inputs.iter().map(|g| self.dist_in.scatter(g)).collect();
        let mid_local = self.dist_mid.local_len();
        let scratch_len = self
            .dist_in
            .local_len()
            .max(mid_local)
            .max(4 * self.shape.iter().copied().max().unwrap());
        // One session per arena; a concurrent execute of this same plan
        // falls back to transient scratch (see ScratchArena).
        let arena_session = self.scratch.begin_session();
        let outcome = try_run_spmd_with(self.p, self.scratch.exec_options(), |ctx: &mut Ctx| {
            let mut scratch_guard;
            let mut owned_scratch;
            let scratch: &mut [C64] = match &arena_session {
                Some(_) => {
                    scratch_guard = self.scratch.lease(ctx.rank(), scratch_len);
                    scratch_guard.as_mut_slice()
                }
                None => {
                    owned_scratch = vec![C64::ZERO; scratch_len];
                    owned_scratch.as_mut_slice()
                }
            };
            let mut outs = Vec::with_capacity(inputs.len());
            for item in &locals {
                let mut local = item[ctx.rank()].clone();
                // Phase 1: transform the d-1 local axes.
                ctx.begin_comp("slab-local-axes");
                for (i, l) in (1..d).enumerate() {
                    transform_axis(&mut local, &self.local_in_shape, l, &self.plans_in[i], &mut scratch, dir);
                    ctx.charge_flops(flops_axis(&self.local_in_shape, l));
                }
                // Phase 2: global transpose so axis 0 becomes local.
                let mut mid = redistribute(ctx, &self.transpose, "slab-transpose", &local);
                // Phase 3: transform axis 0 (it is local in dist_mid).
                ctx.begin_comp("slab-axis0");
                transform_axis(&mut mid, &self.local_mid_shape, 0, &self.plan_axis0, &mut scratch, dir);
                ctx.charge_flops(flops_axis(&self.local_mid_shape, 0));
                outs.push(match self.out {
                    OutputDist::Different => mid,
                    OutputDist::Same => redistribute(ctx, &self.back, "slab-transpose-back", &mid),
                });
            }
            outs
        })
        .map_err(|failure| {
            self.scratch.poison();
            FftError::from(failure)
        })?;
        let final_dist = match self.out {
            OutputDist::Different => &self.dist_mid,
            OutputDist::Same => &self.dist_in,
        };
        Ok((final_dist.gather_batch(&outcome.outputs), outcome.report))
    }
}

/// Real-to-complex slab transform via the packing trick: pack adjacent
/// last-axis pairs, run the slab pipeline on the half shape
/// `[..., n_d/2]`, untangle into the Hermitian half-spectrum
/// (`[..., n_d/2 + 1]`, numpy `rfftn` layout, unnormalized). Gives the
/// conformance suite an FFTW-style baseline to cross-check the
/// distributed r2c against. `p` must satisfy the slab rules on the half
/// shape (`p | n_1` still, since packing only touches the last axis).
pub fn slab_r2c_global(
    shape: &[usize],
    p: usize,
    real: &[f64],
    out: OutputDist,
) -> Result<(Vec<C64>, CostReport), FftError> {
    use crate::fft::realnd::{half_shape, r2c_drive, validate_even_last_axis};
    validate_even_last_axis(shape)?;
    let plan = SlabPlan::new(&half_shape(shape), p, out)?;
    r2c_drive(shape, p, real, |packed| {
        let (mut outs, report) = plan.execute_batch_global(&[packed], Direction::Forward);
        Ok((outs.pop().unwrap(), report))
    })
}

/// One-shot convenience: plan, run once on the BSP machine over a
/// scattered global array, gather.
pub fn slab_global(
    shape: &[usize],
    p: usize,
    global: &[C64],
    dir: Direction,
    out: OutputDist,
) -> Result<(Vec<C64>, CostReport), FftError> {
    let plan = SlabPlan::new(shape, p, out)?;
    let (mut outs, report) = plan.execute_batch_global(&[global], dir);
    Ok((outs.pop().unwrap(), report))
}

/// Model flops for transforming axis `l` of a local array: the paper's
/// per-element convention, `5 log2(n_l)` per element.
fn flops_axis(local_shape: &[usize], l: usize) -> f64 {
    let total: usize = local_shape.iter().product();
    let n = local_shape[l];
    if n <= 1 {
        0.0
    } else {
        5.0 * total as f64 * (n as f64).log2()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::fft::{fftn_inplace, rel_l2_error};
    use crate::testing::Rng;

    fn rand_global(n: usize, rng: &mut Rng) -> Vec<C64> {
        (0..n).map(|_| C64::new(rng.f64_signed(), rng.f64_signed())).collect()
    }

    fn check(shape: &[usize], p: usize, out: OutputDist, want_comm: usize) {
        let mut rng = Rng::new(0x5AB);
        let n: usize = shape.iter().product();
        let x = rand_global(n, &mut rng);
        let mut want = x.clone();
        fftn_inplace(&mut want, shape, Direction::Forward);
        let (got, report) = slab_global(shape, p, &x, Direction::Forward, out).unwrap();
        let err = rel_l2_error(&got, &want);
        assert!(err < 1e-9, "shape {shape:?} p={p} {out:?}: err {err}");
        assert_eq!(report.comm_supersteps(), want_comm, "shape {shape:?} p={p} {out:?}");
    }

    #[test]
    fn slab_2d_3d_correct() {
        check(&[8, 8], 4, OutputDist::Same, 2);
        check(&[8, 8], 4, OutputDist::Different, 1);
        check(&[8, 8, 8], 8, OutputDist::Same, 2);
        check(&[8, 8, 8], 8, OutputDist::Different, 1);
        check(&[16, 4, 4], 4, OutputDist::Same, 2);
    }

    #[test]
    fn slab_needs_higher_rank_second_dist() {
        // The paper's 8x4x2 example: p = 8 forces a 4x2 pencil for the
        // final step.
        check(&[8, 4, 2], 8, OutputDist::Same, 2);
        check(&[8, 4, 2], 8, OutputDist::Different, 1);
    }

    #[test]
    fn slab_pmax_matches_paper() {
        assert_eq!(slab_pmax(&[1024, 1024, 1024]), 1024);
        assert_eq!(slab_pmax(&[64, 64, 64, 64, 64]), 64);
        assert_eq!(slab_pmax(&[1 << 24, 64]), 64);
        assert_eq!(slab_pmax(&[8, 4, 2]), 8);
    }

    #[test]
    fn slab_rejects_p_beyond_pmax_with_typed_error() {
        let x = vec![C64::ZERO; 8 * 4 * 2];
        assert_eq!(
            slab_global(&[8, 4, 2], 16, &x, Direction::Forward, OutputDist::Same).unwrap_err(),
            FftError::TooManyProcs { algo: "slab", p: 16, pmax: 8 }
        );
    }

    #[test]
    fn slab_plan_is_reusable_across_executions() {
        let mut rng = Rng::new(0x5AD);
        let shape = [8usize, 8];
        let plan = SlabPlan::new(&shape, 2, OutputDist::Same).unwrap();
        for _ in 0..3 {
            let x = rand_global(64, &mut rng);
            let mut want = x.clone();
            fftn_inplace(&mut want, &shape, Direction::Forward);
            let (got, rep) = plan.execute_batch_global(&[&x], Direction::Forward);
            assert!(rel_l2_error(&got[0], &want) < 1e-9);
            assert_eq!(rep.comm_supersteps(), 2);
        }
    }

    #[test]
    fn slab_r2c_matches_sequential_rfftn() {
        use crate::fft::realnd::rfftn;
        let mut rng = Rng::new(0x5AE);
        for (shape, p) in [(vec![8usize, 16], 4usize), (vec![8, 4, 8], 2)] {
            let n: usize = shape.iter().product();
            let x: Vec<f64> = (0..n).map(|_| rng.f64_signed()).collect();
            let want = rfftn(&x, &shape);
            for out in [OutputDist::Same, OutputDist::Different] {
                // The untangle needs the gathered global spectrum, which
                // both output distributions deliver identically.
                let (got, _) = slab_r2c_global(&shape, p, &x, out).unwrap();
                let err = rel_l2_error(&got, &want);
                assert!(err < 1e-10, "shape {shape:?} p={p} {out:?}: err {err}");
            }
        }
    }

    #[test]
    fn slab_inverse_roundtrip_via_facade_normalization() {
        use crate::api::{Algorithm, Normalization, Transform};
        let mut rng = Rng::new(0x5AC);
        let shape = [8usize, 8];
        let x = rand_global(64, &mut rng);
        let fwd = Transform::new(&shape).procs(2).plan(Algorithm::slab()).unwrap();
        let y = fwd.execute(&x).unwrap();
        let inv = Transform::new(&shape)
            .procs(2)
            .inverse()
            .normalization(Normalization::ByN)
            .plan(Algorithm::slab())
            .unwrap();
        let z = inv.execute(&y.output).unwrap();
        assert!(crate::fft::max_abs_diff(&z.output, &x) < 1e-9);
    }
}
