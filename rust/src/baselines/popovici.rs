//! Popovici et al. [21]-style cyclic d-step algorithm (§1.2).
//!
//! Like FFTU this uses the d-dimensional cyclic distribution for both
//! input and output, with `p_l <= sqrt(n_l)` processors per axis. Unlike
//! FFTU, it transforms one dimension at a time: for each axis it runs the
//! 1D cyclic-to-cyclic four-step algorithm (Alg. 2.2) across the
//! processors of that axis, so it performs **d** all-to-all communication
//! steps (each moving all data once) against FFTU's single step.
//!
//! Implementation note: round `l` is exactly Algorithm 2.3 applied to the
//! *view* in which only axis `l` is global (length `n_l`, distributed
//! over `p_l` processors) and all other axes are the local batch
//! dimensions. We reuse FFTU's pack/unpack/superstep machinery on that
//! view; the exchange routes packets along rows of the processor grid
//! (all coordinates fixed except `l`). Planning (view plans, per-axis
//! FFT plans) lives in [`PopoviciPlan`].

use std::sync::Arc;

use crate::api::FftError;
use crate::bsp::{try_run_spmd_with, CostReport, Ctx};
use crate::dist::GridDist;
use crate::fft::ndfft::transform_axis;
use crate::fft::{C64, Direction, Plan, Planner};
use crate::fftu::pack::{pack_twiddle, unpack, TwiddleTables};
use crate::fftu::plan::FftuPlan;

/// Same per-axis square-divisor bound as FFTU.
pub fn popovici_pmax(shape: &[usize]) -> usize {
    crate::fftu::fftu_pmax(shape)
}

/// Validated, fully planned d-step cyclic pipeline: one FFTU "view" plan
/// per axis plus the local/strided FFT plans each round needs.
pub struct PopoviciPlan {
    shape: Vec<usize>,
    pgrid: Vec<usize>,
    dist: GridDist,
    local_shape: Vec<usize>,
    view_plans: Vec<Arc<FftuPlan>>,
    /// `F_{n_l/p_l}` of each round's local transform.
    axis_plans: Vec<Arc<Plan>>,
    /// `F_{p_l}` of each round's strided transform.
    fp_plans: Vec<Arc<Plan>>,
    /// Per-rank scratch persisted across executes (arena reuse).
    scratch: super::ScratchArena,
}

impl std::fmt::Debug for PopoviciPlan {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("PopoviciPlan")
            .field("shape", &self.shape)
            .field("pgrid", &self.pgrid)
            .finish_non_exhaustive()
    }
}

impl PopoviciPlan {
    pub fn new(shape: &[usize], pgrid: &[usize]) -> Result<Self, FftError> {
        let d = shape.len();
        if d != pgrid.len() {
            return Err(FftError::RankMismatch { shape: d, grid: pgrid.len() });
        }
        for (axis, (&n, &p)) in shape.iter().zip(pgrid).enumerate() {
            if p == 0 {
                return Err(FftError::AxisConstraint { axis, n, p, requires: "p_l >= 1" });
            }
            if n % (p * p) != 0 {
                return Err(FftError::AxisConstraint { axis, n, p, requires: "p_l^2 | n_l" });
            }
        }
        let dist = GridDist::cyclic(shape, pgrid)?;
        let planner = Planner::new();
        let local_shape: Vec<usize> = shape.iter().zip(pgrid).map(|(&n, &p)| n / p).collect();
        let mut view_plans: Vec<Arc<FftuPlan>> = Vec::with_capacity(d);
        for l in 0..d {
            let mut vshape = local_shape.clone();
            vshape[l] = shape[l];
            let mut vgrid = vec![1usize; d];
            vgrid[l] = pgrid[l];
            view_plans.push(Arc::new(FftuPlan::new(&vshape, &vgrid, &planner)?));
        }
        let axis_plans: Vec<Arc<Plan>> =
            local_shape.iter().map(|&n| planner.plan(n)).collect();
        let fp_plans: Vec<Arc<Plan>> = pgrid.iter().map(|&p| planner.plan(p)).collect();
        Ok(PopoviciPlan {
            shape: shape.to_vec(),
            pgrid: pgrid.to_vec(),
            dist,
            local_shape,
            view_plans,
            axis_plans,
            fp_plans,
            scratch: super::ScratchArena::new(pgrid.iter().product()),
        })
    }

    pub fn num_procs(&self) -> usize {
        self.pgrid.iter().product()
    }

    pub fn input_dist(&self) -> &GridDist {
        &self.dist
    }

    /// The per-axis processor grid.
    pub fn pgrid(&self) -> &[usize] {
        &self.pgrid
    }

    /// Packet size of round `l`'s all-to-all: every rank sends this many
    /// words to each of the `p_l` ranks in its axis-`l` grid row (the
    /// self-packet included, which the exchange skips when charging).
    /// The static verifier reads this at plan time; no payload is
    /// touched.
    pub fn axis_packet_len(&self, l: usize) -> usize {
        self.view_plans[l].packet_len()
    }

    /// Session options (superstep deadline, fault injection) for every
    /// subsequent execute of this plan.
    pub fn set_exec_options(&self, opts: crate::bsp::SpmdOptions) {
        self.scratch.set_exec_options(opts);
    }

    /// Execute on whole (global) arrays; the report covers the batch.
    /// Panicking wrapper over [`PopoviciPlan::try_execute_batch_global`].
    pub fn execute_batch_global(
        &self,
        inputs: &[&[C64]],
        dir: Direction,
    ) -> (Vec<Vec<C64>>, CostReport) {
        self.try_execute_batch_global(inputs, dir).unwrap_or_else(|e| panic!("{e}"))
    }

    /// Fallible execute: BSP session failures surface as
    /// [`FftError::RankFailure`] / [`FftError::Timeout`] and poison the
    /// scratch arena (rebuilt transparently on the next execute).
    pub fn try_execute_batch_global(
        &self,
        inputs: &[&[C64]],
        dir: Direction,
    ) -> Result<(Vec<Vec<C64>>, CostReport), FftError> {
        let d = self.shape.len();
        let p = self.num_procs();
        let locals: Vec<Vec<Vec<C64>>> = inputs.iter().map(|g| self.dist.scatter(g)).collect();
        let max_axis = *self.shape.iter().max().unwrap();
        let scratch_len = self.dist.local_len().max(4 * max_axis);
        // One session per arena; a concurrent execute of this same plan
        // falls back to transient scratch (see ScratchArena).
        let arena_session = self.scratch.begin_session();
        let outcome = try_run_spmd_with(p, self.scratch.exec_options(), |ctx: &mut Ctx| {
            let coords = self.dist.proc_coords(ctx.rank());
            let mut scratch_guard;
            let mut owned_scratch;
            let scratch: &mut [C64] = match &arena_session {
                Some(_) => {
                    scratch_guard = self.scratch.lease(ctx.rank(), scratch_len);
                    scratch_guard.as_mut_slice()
                }
                None => {
                    owned_scratch = vec![C64::ZERO; scratch_len];
                    owned_scratch.as_mut_slice()
                }
            };
            let mut outs = Vec::with_capacity(inputs.len());
            for item in &locals {
                let mut local = item[ctx.rank()].clone();
                for l in 0..d {
                    let vplan = &self.view_plans[l];
                    let p_l = self.pgrid[l];
                    // View coordinates: only axis l is distributed.
                    let mut vcoords = vec![0usize; d];
                    vcoords[l] = coords[l];
                    let tables = TwiddleTables::new(vplan, &vcoords);
                    // Superstep 0 of the view: local FFT along axis l + twiddle.
                    ctx.begin_comp("popovici-local-fft");
                    transform_axis(
                        &mut local,
                        &self.local_shape,
                        l,
                        &self.axis_plans[l],
                        &mut scratch,
                        dir,
                    );
                    // 5 (N/p) log2(n_l/p_l) for the axis-l lines + 12 N/p twiddle.
                    let len_l = self.local_shape[l] as f64;
                    let ss0 = if self.local_shape[l] > 1 {
                        5.0 * local.len() as f64 * len_l.log2()
                    } else {
                        0.0
                    };
                    ctx.charge_flops(ss0 + vplan.flops_twiddle());
                    let mut packets = vec![vec![C64::ZERO; vplan.packet_len()]; p_l];
                    pack_twiddle(vplan, &tables, &local, &mut packets, dir);
                    // Superstep 1: exchange along the axis-l row of the grid.
                    let mut outgoing: Vec<Vec<C64>> = (0..p).map(|_| Vec::new()).collect();
                    for (k, packet) in packets.into_iter().enumerate() {
                        let mut tc = coords.clone();
                        tc[l] = k;
                        outgoing[self.dist.proc_rank(&tc)] = packet;
                    }
                    let mut incoming_all = ctx.exchange("popovici-alltoall", outgoing);
                    let mut incoming: Vec<Vec<C64>> = Vec::with_capacity(p_l);
                    for k in 0..p_l {
                        let mut tc = coords.clone();
                        tc[l] = k;
                        incoming.push(std::mem::take(&mut incoming_all[self.dist.proc_rank(&tc)]));
                    }
                    unpack(vplan, &incoming, &mut local);
                    // Superstep 2 of the view: strided F_{p_l} along axis l.
                    ctx.begin_comp("popovici-strided-fft");
                    if p_l > 1 {
                        let inner: usize = self.local_shape[l + 1..].iter().product();
                        let per = self.shape[l] / (p_l * p_l);
                        let chunk = self.local_shape[l] * inner;
                        let stride = per * inner;
                        for block in local.chunks_exact_mut(chunk) {
                            self.fp_plans[l].execute_interleaved(block, &mut scratch, stride, dir);
                        }
                    }
                    ctx.charge_flops(vplan.flops_superstep2());
                }
                outs.push(local);
            }
            outs
        })
        .map_err(|failure| {
            self.scratch.poison();
            FftError::from(failure)
        })?;
        Ok((self.dist.gather_batch(&outcome.outputs), outcome.report))
    }
}

/// One-shot convenience: plan, run once on the BSP machine, gather.
pub fn popovici_global(
    shape: &[usize],
    pgrid: &[usize],
    global: &[C64],
    dir: Direction,
) -> Result<(Vec<C64>, CostReport), FftError> {
    let plan = PopoviciPlan::new(shape, pgrid)?;
    let (mut outs, report) = plan.execute_batch_global(&[global], dir);
    Ok((outs.pop().unwrap(), report))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::fft::{fftn_inplace, max_abs_diff, rel_l2_error};
    use crate::testing::Rng;

    fn check(shape: &[usize], pgrid: &[usize]) {
        let mut rng = Rng::new(0xD0);
        let n: usize = shape.iter().product();
        let x: Vec<C64> =
            (0..n).map(|_| C64::new(rng.f64_signed(), rng.f64_signed())).collect();
        let mut want = x.clone();
        fftn_inplace(&mut want, shape, Direction::Forward);
        let (got, report) = popovici_global(shape, pgrid, &x, Direction::Forward).unwrap();
        let err = rel_l2_error(&got, &want);
        assert!(err < 1e-9, "shape {shape:?} grid {pgrid:?}: err {err}");
        // One all-to-all per *distributed* dimension; undistributed axes
        // still count as a superstep in this implementation, so expect d.
        assert_eq!(report.comm_supersteps(), shape.len());
    }

    #[test]
    fn popovici_2d_3d_correct() {
        check(&[16, 16], &[2, 2]);
        check(&[16, 8], &[4, 2]);
        check(&[8, 8, 8], &[2, 2, 2]);
    }

    #[test]
    fn popovici_roundtrip_via_facade_normalization() {
        use crate::api::{Algorithm, Normalization, Transform};
        let mut rng = Rng::new(0xD1);
        let shape = [16usize, 16];
        let n = 256;
        let x: Vec<C64> =
            (0..n).map(|_| C64::new(rng.f64_signed(), rng.f64_signed())).collect();
        let fwd = Transform::new(&shape).grid(&[2, 2]).plan(Algorithm::Popovici).unwrap();
        let y = fwd.execute(&x).unwrap();
        let inv = Transform::new(&shape)
            .grid(&[2, 2])
            .inverse()
            .normalization(Normalization::ByN)
            .plan(Algorithm::Popovici)
            .unwrap();
        let z = inv.execute(&y.output).unwrap();
        assert!(max_abs_diff(&z.output, &x) < 1e-9);
    }

    #[test]
    fn popovici_pmax_equals_fftu() {
        assert_eq!(popovici_pmax(&[1024, 1024, 1024]), 32_768);
    }

    #[test]
    fn popovici_rejects_bad_grid_with_typed_error() {
        let x = vec![C64::ZERO; 64];
        assert_eq!(
            popovici_global(&[8, 8], &[4, 1], &x, Direction::Forward).unwrap_err(),
            FftError::AxisConstraint { axis: 0, n: 8, p: 4, requires: "p_l^2 | n_l" }
        );
    }
}
