//! Popovici et al. [21]-style cyclic d-step algorithm (§1.2).
//!
//! Like FFTU this uses the d-dimensional cyclic distribution for both
//! input and output, with `p_l <= sqrt(n_l)` processors per axis. Unlike
//! FFTU, it transforms one dimension at a time: for each axis it runs the
//! 1D cyclic-to-cyclic four-step algorithm (Alg. 2.2) across the
//! processors of that axis, so it performs **d** all-to-all communication
//! steps (each moving all data once) against FFTU's single step.
//!
//! Implementation note: round `l` is exactly Algorithm 2.3 applied to the
//! *view* in which only axis `l` is global (length `n_l`, distributed
//! over `p_l` processors) and all other axes are the local batch
//! dimensions. We reuse FFTU's pack/unpack/superstep machinery on that
//! view; the exchange routes packets along rows of the processor grid
//! (all coordinates fixed except `l`).

use std::sync::Arc;

use crate::bsp::{run_spmd, CostReport, Ctx};
use crate::dist::GridDist;
use crate::fft::ndfft::transform_axis;
use crate::fft::{C64, Direction, Planner};
use crate::fftu::pack::{pack_twiddle, unpack, TwiddleTables};
use crate::fftu::plan::FftuPlan;

/// Same per-axis square-divisor bound as FFTU.
pub fn popovici_pmax(shape: &[usize]) -> usize {
    crate::fftu::fftu_pmax(shape)
}

/// Run the d-step cyclic algorithm on the BSP machine.
pub fn popovici_global(
    shape: &[usize],
    pgrid: &[usize],
    global: &[C64],
    dir: Direction,
) -> Result<(Vec<C64>, CostReport), String> {
    let d = shape.len();
    let dist = GridDist::cyclic(shape, pgrid)?;
    for (&n, &p) in shape.iter().zip(pgrid) {
        if n % (p * p) != 0 {
            return Err(format!("popovici requires p_l^2 | n_l; violated: p={p}, n={n}"));
        }
    }
    let planner = Planner::new();
    // Per-axis view plans: axis l global, everything else is batch.
    let mut view_plans: Vec<Arc<FftuPlan>> = Vec::with_capacity(d);
    let local_shape: Vec<usize> = shape.iter().zip(pgrid).map(|(&n, &p)| n / p).collect();
    for l in 0..d {
        let mut vshape = local_shape.clone();
        vshape[l] = shape[l];
        let mut vgrid = vec![1usize; d];
        vgrid[l] = pgrid[l];
        view_plans.push(Arc::new(FftuPlan::new(&vshape, &vgrid, &planner)?));
    }
    let p: usize = pgrid.iter().product();
    let locals = dist.scatter(global);

    let outcome = run_spmd(p, |ctx: &mut Ctx| {
        let mut local = locals[ctx.rank()].clone();
        let coords = dist.proc_coords(ctx.rank());
        let mut scratch =
            vec![C64::ZERO; local.len().max(4 * shape.iter().copied().max().unwrap())];
        for l in 0..d {
            let vplan = &view_plans[l];
            let p_l = pgrid[l];
            // View coordinates: only axis l is distributed.
            let mut vcoords = vec![0usize; d];
            vcoords[l] = coords[l];
            let tables = TwiddleTables::new(vplan, &vcoords);
            // Superstep 0 of the view: local FFT along axis l + twiddle.
            ctx.begin_comp("popovici-local-fft");
            let axis_plan = planner.plan(local_shape[l]);
            transform_axis(&mut local, &local_shape, l, &axis_plan, &mut scratch, dir);
            // 5 (N/p) log2(n_l/p_l) for the axis-l lines + 12 N/p twiddle.
            let len_l = local_shape[l] as f64;
            let ss0 = if local_shape[l] > 1 {
                5.0 * local.len() as f64 * len_l.log2()
            } else {
                0.0
            };
            ctx.charge_flops(ss0 + vplan.flops_twiddle());
            let mut packets = vec![vec![C64::ZERO; vplan.packet_len()]; p_l];
            pack_twiddle(vplan, &tables, &local, &mut packets, dir);
            // Superstep 1: exchange along the axis-l row of the grid.
            let mut outgoing: Vec<Vec<C64>> = (0..p).map(|_| Vec::new()).collect();
            for (k, packet) in packets.into_iter().enumerate() {
                let mut tc = coords.clone();
                tc[l] = k;
                outgoing[dist.proc_rank(&tc)] = packet;
            }
            let mut incoming_all = ctx.exchange("popovici-alltoall", outgoing);
            let mut incoming: Vec<Vec<C64>> = Vec::with_capacity(p_l);
            for k in 0..p_l {
                let mut tc = coords.clone();
                tc[l] = k;
                incoming.push(std::mem::take(&mut incoming_all[dist.proc_rank(&tc)]));
            }
            unpack(vplan, &incoming, &mut local);
            // Superstep 2 of the view: strided F_{p_l} along axis l.
            ctx.begin_comp("popovici-strided-fft");
            if p_l > 1 {
                let inner: usize = local_shape[l + 1..].iter().product();
                let per = shape[l] / (p_l * p_l);
                let chunk = local_shape[l] * inner;
                let stride = per * inner;
                let fp = planner.plan(p_l);
                for block in local.chunks_exact_mut(chunk) {
                    fp.execute_interleaved(block, &mut scratch, stride, dir);
                }
            }
            ctx.charge_flops(vplan.flops_superstep2());
        }
        local
    });
    Ok((dist.gather(&outcome.outputs), outcome.report))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::fft::{fftn_inplace, max_abs_diff, rel_l2_error};
    use crate::testing::Rng;

    fn check(shape: &[usize], pgrid: &[usize]) {
        let mut rng = Rng::new(0xD0);
        let n: usize = shape.iter().product();
        let x: Vec<C64> =
            (0..n).map(|_| C64::new(rng.f64_signed(), rng.f64_signed())).collect();
        let mut want = x.clone();
        fftn_inplace(&mut want, shape, Direction::Forward);
        let (got, report) = popovici_global(shape, pgrid, &x, Direction::Forward).unwrap();
        let err = rel_l2_error(&got, &want);
        assert!(err < 1e-9, "shape {shape:?} grid {pgrid:?}: err {err}");
        // One all-to-all per *distributed* dimension; undistributed axes
        // still count as a superstep in this implementation, so expect d.
        assert_eq!(report.comm_supersteps(), shape.len());
    }

    #[test]
    fn popovici_2d_3d_correct() {
        check(&[16, 16], &[2, 2]);
        check(&[16, 8], &[4, 2]);
        check(&[8, 8, 8], &[2, 2, 2]);
    }

    #[test]
    fn popovici_roundtrip() {
        let mut rng = Rng::new(0xD1);
        let shape = [16usize, 16];
        let pgrid = [2usize, 2];
        let n = 256;
        let x: Vec<C64> =
            (0..n).map(|_| C64::new(rng.f64_signed(), rng.f64_signed())).collect();
        let (y, _) = popovici_global(&shape, &pgrid, &x, Direction::Forward).unwrap();
        let (z, _) = popovici_global(&shape, &pgrid, &y, Direction::Inverse).unwrap();
        let z: Vec<C64> = z.iter().map(|v| *v / n as f64).collect();
        assert!(max_abs_diff(&z, &x) < 1e-9);
    }

    #[test]
    fn popovici_pmax_equals_fftu() {
        assert_eq!(popovici_pmax(&[1024, 1024, 1024]), 32_768);
    }
}
