//! heFFTe-style brick-to-brick pipeline (§1.2).
//!
//! heFFTe's input and output are d-dimensional blocks ("bricks"); it
//! internally reshapes to pencil distributions by *tensor transpositions*
//! (its name for the all-to-all), transforms one axis per pencil
//! orientation, and reshapes back to bricks on output. For a 3D array
//! this is the brick -> pencil-z -> pencil-y -> pencil-x -> brick
//! pipeline of the heFFTe paper, with d + 1 communication steps.

use std::sync::Arc;

use crate::bsp::{redistribute, run_spmd, CostReport, Ctx};
use crate::dist::{GridDist, RedistPlan};
use crate::fft::ndfft::transform_axis;
use crate::fft::{C64, Direction, Plan, Planner};

use super::pencil::fit_grid;

/// heFFTe is bound by its pencil stages exactly like PFFT with r = d-1
/// processors axes available per stage; in practice its brick grid bounds
/// p by `prod_l n_l / 2^d`-ish, but the pencil stages are the binding
/// constraint we model: p must fit on d-1 axes at every stage.
pub fn heffte_pmax(shape: &[usize]) -> usize {
    let d = shape.len();
    // Worst stage: processors sit on all axes except the transformed
    // one; the binding stage excludes the largest axis.
    let total: usize = shape.iter().product();
    let max_axis = *shape.iter().max().unwrap();
    let _ = d;
    total / max_axis
}

/// The heFFTe pipeline's distribution chain: brick, one pencil per axis
/// (last axis first), brick again. Shared by the executor and the
/// analytic cost model.
pub fn heffte_schedule(
    shape: &[usize],
    p: usize,
) -> Result<(Vec<GridDist>, Vec<usize>), String> {
    let d = shape.len();
    let all_axes: Vec<usize> = (0..d).collect();
    let brick_grid = fit_grid(shape, &all_axes, p)
        .ok_or_else(|| format!("cannot build a {p}-processor brick grid for {shape:?}"))?;
    let dist_brick = GridDist::blocks(shape, &brick_grid)?;
    let mut dists: Vec<GridDist> = vec![dist_brick.clone()];
    let mut stage_axis: Vec<usize> = Vec::new();
    for l in (0..d).rev() {
        let allowed: Vec<usize> = (0..d).filter(|&m| m != l).collect();
        let grid = fit_grid(shape, &allowed, p)
            .ok_or_else(|| format!("cannot place {p} processors avoiding axis {l}"))?;
        dists.push(GridDist::blocks(shape, &grid)?);
        stage_axis.push(l);
    }
    dists.push(dist_brick); // reshape back to bricks
    Ok((dists, stage_axis))
}

/// Run the brick-to-brick heFFTe-like pipeline.
pub fn heffte_global(
    shape: &[usize],
    p: usize,
    global: &[C64],
    dir: Direction,
) -> Result<(Vec<C64>, CostReport), String> {
    let (dists, stage_axis) = heffte_schedule(shape, p)?;
    let dist_brick = dists[0].clone();
    let mut redists: Vec<RedistPlan> = Vec::new();
    for w in dists.windows(2) {
        redists.push(RedistPlan::new(&w[0], &w[1])?);
    }

    let planner = Planner::new();
    let axis_plan: Vec<Arc<Plan>> = shape.iter().map(|&n| planner.plan(n)).collect();
    let locals = dist_brick.scatter(global);
    let outcome = run_spmd(p, |ctx: &mut Ctx| {
        let mut local = locals[ctx.rank()].clone();
        let max_axis = *shape.iter().max().unwrap();
        let mut scratch = vec![C64::ZERO; local.len().max(4 * max_axis)];
        for (i, &l) in stage_axis.iter().enumerate() {
            local = redistribute(ctx, &redists[i], "heffte-reshape", &local);
            if scratch.len() < local.len() {
                scratch.resize(local.len(), C64::ZERO);
            }
            ctx.begin_comp("heffte-axis");
            let lshape = dists[i + 1].local_shape().to_vec();
            transform_axis(&mut local, &lshape, l, &axis_plan[l], &mut scratch, dir);
            let n = lshape[l] as f64;
            ctx.charge_flops(5.0 * local.len() as f64 * n.log2());
        }
        // Final reshape back to bricks.
        redistribute(ctx, redists.last().unwrap(), "heffte-reshape-out", &local)
    });
    Ok((dist_brick.gather(&outcome.outputs), outcome.report))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::fft::{fftn_inplace, rel_l2_error};
    use crate::testing::Rng;

    #[test]
    fn heffte_3d_correct_with_d_plus_1_reshapes() {
        let shape = [8usize, 8, 8];
        let n: usize = shape.iter().product();
        let mut rng = Rng::new(0x4EF);
        let x: Vec<C64> = (0..n).map(|_| C64::new(rng.f64_signed(), rng.f64_signed())).collect();
        let mut want = x.clone();
        fftn_inplace(&mut want, &shape, Direction::Forward);
        let (got, report) = heffte_global(&shape, 8, &x, Direction::Forward).unwrap();
        assert!(rel_l2_error(&got, &want) < 1e-9);
        // d pencil reshapes + 1 brick reshape out = 4 for d = 3.
        assert_eq!(report.comm_supersteps(), 4);
    }

    #[test]
    fn heffte_2d_correct() {
        let shape = [8usize, 4];
        let n: usize = shape.iter().product();
        let mut rng = Rng::new(0x4F0);
        let x: Vec<C64> = (0..n).map(|_| C64::new(rng.f64_signed(), rng.f64_signed())).collect();
        let mut want = x.clone();
        fftn_inplace(&mut want, &shape, Direction::Forward);
        let (got, report) = heffte_global(&shape, 4, &x, Direction::Forward).unwrap();
        assert!(rel_l2_error(&got, &want) < 1e-9);
        assert_eq!(report.comm_supersteps(), 3);
    }

    #[test]
    fn heffte_pmax_excludes_largest_axis() {
        assert_eq!(heffte_pmax(&[1024, 1024, 1024]), 1 << 20);
        assert_eq!(heffte_pmax(&[1 << 24, 64]), 64);
    }
}
