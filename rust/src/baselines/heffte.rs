//! heFFTe-style brick-to-brick pipeline (§1.2).
//!
//! heFFTe's input and output are d-dimensional blocks ("bricks"); it
//! internally reshapes to pencil distributions by *tensor transpositions*
//! (its name for the all-to-all), transforms one axis per pencil
//! orientation, and reshapes back to bricks on output. For a 3D array
//! this is the brick -> pencil-z -> pencil-y -> pencil-x -> brick
//! pipeline of the heFFTe paper, with d + 1 communication steps.
//!
//! Planning (distribution chain, compiled reshapes, local FFT plans)
//! lives in [`HefftePlan`]; [`heffte_global`] is the one-shot wrapper.

use std::sync::Arc;

use crate::api::FftError;
use super::ScratchArena;
use crate::bsp::{redistribute, try_run_spmd_with, CostReport, Ctx};
use crate::dist::{GridDist, RedistPlan};
use crate::fft::ndfft::transform_axis;
use crate::fft::{C64, Direction, Plan, Planner};

use super::pencil::fit_grid;

/// heFFTe is bound by its pencil stages exactly like PFFT with r = d-1
/// processors axes available per stage; in practice its brick grid bounds
/// p by `prod_l n_l / 2^d`-ish, but the pencil stages are the binding
/// constraint we model: p must fit on d-1 axes at every stage.
pub fn heffte_pmax(shape: &[usize]) -> usize {
    // Worst stage: processors sit on all axes except the transformed
    // one; the binding stage excludes the largest axis.
    let total: usize = shape.iter().product();
    let max_axis = *shape.iter().max().unwrap();
    total / max_axis
}

/// The heFFTe pipeline's distribution chain: brick, one pencil per axis
/// (last axis first), brick again. Shared by the executor and the
/// analytic cost model.
pub fn heffte_schedule(
    shape: &[usize],
    p: usize,
) -> Result<(Vec<GridDist>, Vec<usize>), FftError> {
    let d = shape.len();
    let all_axes: Vec<usize> = (0..d).collect();
    let brick_grid = fit_grid(shape, &all_axes, p)
        .ok_or(FftError::NoValidGrid { p, pmax: heffte_pmax(shape) })?;
    let dist_brick = GridDist::blocks(shape, &brick_grid)?;
    let mut dists: Vec<GridDist> = vec![dist_brick.clone()];
    let mut stage_axis: Vec<usize> = Vec::new();
    for l in (0..d).rev() {
        let allowed: Vec<usize> = (0..d).filter(|&m| m != l).collect();
        let grid = fit_grid(shape, &allowed, p)
            .ok_or(FftError::NoValidGrid { p, pmax: heffte_pmax(shape) })?;
        dists.push(GridDist::blocks(shape, &grid)?);
        stage_axis.push(l);
    }
    dists.push(dist_brick); // reshape back to bricks
    Ok((dists, stage_axis))
}

/// Validated, fully planned brick-to-brick heFFTe-like pipeline.
pub struct HefftePlan {
    shape: Vec<usize>,
    p: usize,
    dists: Vec<GridDist>,
    stage_axis: Vec<usize>,
    redists: Vec<RedistPlan>,
    axis_plan: Vec<Arc<Plan>>,
    /// Per-rank scratch persisted across executes (arena reuse).
    scratch: ScratchArena,
}

impl std::fmt::Debug for HefftePlan {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("HefftePlan")
            .field("shape", &self.shape)
            .field("p", &self.p)
            .field("stages", &self.stage_axis.len())
            .finish_non_exhaustive()
    }
}

impl HefftePlan {
    pub fn new(shape: &[usize], p: usize) -> Result<Self, FftError> {
        let (dists, stage_axis) = heffte_schedule(shape, p)?;
        let mut redists: Vec<RedistPlan> = Vec::new();
        for w in dists.windows(2) {
            redists.push(RedistPlan::new(&w[0], &w[1])?);
        }
        let planner = Planner::new();
        let axis_plan: Vec<Arc<Plan>> = shape.iter().map(|&n| planner.plan(n)).collect();
        Ok(HefftePlan {
            shape: shape.to_vec(),
            p,
            dists,
            stage_axis,
            redists,
            axis_plan,
            scratch: ScratchArena::new(p),
        })
    }

    pub fn num_procs(&self) -> usize {
        self.p
    }

    /// The brick distribution the input and output live in.
    pub fn input_dist(&self) -> &GridDist {
        &self.dists[0]
    }

    /// The compiled reshapes in execution order: one per FFT stage plus
    /// the final brick reshape out (the static verifier reads their send
    /// matrices; no payload is touched).
    pub fn redist_plans(&self) -> &[RedistPlan] {
        &self.redists
    }

    /// The axis transformed after each of the first
    /// `redist_plans().len() - 1` reshapes.
    pub fn stage_axes(&self) -> &[usize] {
        &self.stage_axis
    }

    /// Set the BSP session options (superstep deadline, fault
    /// injection) used by subsequent executes of this plan.
    pub fn set_exec_options(&self, opts: crate::bsp::SpmdOptions) {
        self.scratch.set_exec_options(opts);
    }

    /// Execute on whole (global) arrays; the report covers the batch.
    /// Panics on a BSP session failure — use
    /// [`Self::try_execute_batch_global`] for typed recovery.
    pub fn execute_batch_global(
        &self,
        inputs: &[&[C64]],
        dir: Direction,
    ) -> (Vec<Vec<C64>>, CostReport) {
        self.try_execute_batch_global(inputs, dir)
            .unwrap_or_else(|e| panic!("{e}"))
    }

    /// Execute on whole (global) arrays, surfacing BSP session failures
    /// (injected faults, protocol violations, timeouts) as typed
    /// errors. An abnormal exit poisons the scratch arena; the next
    /// execute rebuilds it transparently.
    pub fn try_execute_batch_global(
        &self,
        inputs: &[&[C64]],
        dir: Direction,
    ) -> Result<(Vec<Vec<C64>>, CostReport), FftError> {
        let dist_brick = &self.dists[0];
        let locals: Vec<Vec<Vec<C64>>> = inputs.iter().map(|g| dist_brick.scatter(g)).collect();
        // Largest scratch any stage needs, known at plan time.
        let max_axis = *self.shape.iter().max().unwrap();
        let scratch_len = self
            .dists
            .iter()
            .map(|d| d.local_len())
            .fold(4 * max_axis, usize::max);
        // One session per arena; a concurrent execute of this same plan
        // falls back to transient scratch (see ScratchArena).
        let arena_session = self.scratch.begin_session();
        let outcome = try_run_spmd_with(self.p, self.scratch.exec_options(), |ctx: &mut Ctx| {
            let mut scratch_guard;
            let mut owned_scratch;
            let scratch: &mut [C64] = match &arena_session {
                Some(_) => {
                    scratch_guard = self.scratch.lease(ctx.rank(), scratch_len);
                    scratch_guard.as_mut_slice()
                }
                None => {
                    owned_scratch = vec![C64::ZERO; scratch_len];
                    owned_scratch.as_mut_slice()
                }
            };
            let mut outs = Vec::with_capacity(inputs.len());
            for item in &locals {
                let mut local = item[ctx.rank()].clone();
                for (i, &l) in self.stage_axis.iter().enumerate() {
                    local = redistribute(ctx, &self.redists[i], "heffte-reshape", &local);
                    debug_assert!(scratch.len() >= local.len(), "plan-time scratch bound wrong");
                    ctx.begin_comp("heffte-axis");
                    let lshape = self.dists[i + 1].local_shape();
                    transform_axis(&mut local, lshape, l, &self.axis_plan[l], &mut scratch, dir);
                    let n = lshape[l] as f64;
                    ctx.charge_flops(5.0 * local.len() as f64 * n.log2());
                }
                // Final reshape back to bricks.
                outs.push(redistribute(
                    ctx,
                    self.redists.last().unwrap(),
                    "heffte-reshape-out",
                    &local,
                ));
            }
            outs
        })
        .map_err(|failure| {
            self.scratch.poison();
            FftError::from(failure)
        })?;
        Ok((dist_brick.gather_batch(&outcome.outputs), outcome.report))
    }
}

/// One-shot convenience: plan, run once, gather.
pub fn heffte_global(
    shape: &[usize],
    p: usize,
    global: &[C64],
    dir: Direction,
) -> Result<(Vec<C64>, CostReport), FftError> {
    let plan = HefftePlan::new(shape, p)?;
    let (mut outs, report) = plan.execute_batch_global(&[global], dir);
    Ok((outs.pop().unwrap(), report))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::fft::{fftn_inplace, rel_l2_error};
    use crate::testing::Rng;

    #[test]
    fn heffte_3d_correct_with_d_plus_1_reshapes() {
        let shape = [8usize, 8, 8];
        let n: usize = shape.iter().product();
        let mut rng = Rng::new(0x4EF);
        let x: Vec<C64> = (0..n).map(|_| C64::new(rng.f64_signed(), rng.f64_signed())).collect();
        let mut want = x.clone();
        fftn_inplace(&mut want, &shape, Direction::Forward);
        let (got, report) = heffte_global(&shape, 8, &x, Direction::Forward).unwrap();
        assert!(rel_l2_error(&got, &want) < 1e-9);
        // d pencil reshapes + 1 brick reshape out = 4 for d = 3.
        assert_eq!(report.comm_supersteps(), 4);
    }

    #[test]
    fn heffte_2d_correct() {
        let shape = [8usize, 4];
        let n: usize = shape.iter().product();
        let mut rng = Rng::new(0x4F0);
        let x: Vec<C64> = (0..n).map(|_| C64::new(rng.f64_signed(), rng.f64_signed())).collect();
        let mut want = x.clone();
        fftn_inplace(&mut want, &shape, Direction::Forward);
        let (got, report) = heffte_global(&shape, 4, &x, Direction::Forward).unwrap();
        assert!(rel_l2_error(&got, &want) < 1e-9);
        assert_eq!(report.comm_supersteps(), 3);
    }

    #[test]
    fn heffte_pmax_excludes_largest_axis() {
        assert_eq!(heffte_pmax(&[1024, 1024, 1024]), 1 << 20);
        assert_eq!(heffte_pmax(&[1 << 24, 64]), 64);
    }

    #[test]
    fn heffte_plan_reuse_and_typed_errors() {
        let shape = [8usize, 4];
        let plan = HefftePlan::new(&shape, 4).unwrap();
        let mut rng = Rng::new(0x4F1);
        for _ in 0..2 {
            let x: Vec<C64> =
                (0..32).map(|_| C64::new(rng.f64_signed(), rng.f64_signed())).collect();
            let mut want = x.clone();
            fftn_inplace(&mut want, &shape, Direction::Forward);
            let (got, _) = plan.execute_batch_global(&[&x], Direction::Forward);
            assert!(rel_l2_error(&got[0], &want) < 1e-9);
        }
        assert!(matches!(
            HefftePlan::new(&[4, 4], 64),
            Err(FftError::NoValidGrid { p: 64, .. })
        ));
    }
}
