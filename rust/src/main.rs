//! `fftu` — the launcher binary. See `fftu help` / README.md.

fn main() {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    std::process::exit(fftu::cli::dispatch(argv));
}
