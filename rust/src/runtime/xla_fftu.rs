//! FFTU over AOT artifacts: the request-path configuration in which the
//! local superstep computations run through the PJRT-compiled JAX/Pallas
//! modules instead of the native Rust FFT library.
//!
//! Execution is sequential-SPMD (ranks iterated on one thread): the
//! `xla` crate's executables wrap raw PJRT pointers that are not
//! `Sync`, so sharing them across BSP worker threads is unsound. The
//! communication structure (pack -> single all-to-all -> unpack) is
//! identical to the threaded native path and is exercised through the
//! same `FftuPlan` shapes; wall-clock parallel measurements use the
//! native engine.

use std::path::Path;
use std::sync::Arc;

use anyhow::{anyhow, Context, Result};

use crate::fft::{C64, Direction, Planner};
use crate::fftu::{unpack, FftuPlan, TwiddleTables};

use super::engine::{split_planes, XlaEngine, XlaModule};
use super::manifest::{Manifest, ModuleKind};

/// FFTU bound to a specific (shape, pgrid) configuration's artifacts.
pub struct XlaFftu {
    pub plan: Arc<FftuPlan>,
    ss0_fwd: XlaModule,
    ss0_inv: XlaModule,
    ss2_fwd: XlaModule,
    ss2_inv: XlaModule,
}

impl std::fmt::Debug for XlaFftu {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("XlaFftu")
            .field("shape", &self.plan.shape)
            .field("pgrid", &self.plan.pgrid)
            .finish_non_exhaustive()
    }
}

impl XlaFftu {
    /// Load the four modules (ss0/ss2 x fwd/inv) for a configuration.
    pub fn load(artifacts: &Path, shape: &[usize], pgrid: &[usize]) -> Result<Self> {
        let manifest = Manifest::load(artifacts).map_err(|e| anyhow!(e))?;
        let engine = XlaEngine::cpu()?;
        let planner = Planner::new();
        let plan =
            Arc::new(FftuPlan::new(shape, pgrid, &planner).map_err(|e| anyhow!(e))?);
        let get = |kind: ModuleKind, inverse: bool| -> Result<XlaModule> {
            let entry = manifest.find(kind, shape, pgrid, inverse).with_context(|| {
                format!(
                    "no artifact for kind={kind:?} shape={shape:?} pgrid={pgrid:?} inverse={inverse} \
                     (add the config to aot.py CONFIGS and re-run `make artifacts`)"
                )
            })?;
            engine.load(&entry.file, &entry.name, 2)
        };
        Ok(XlaFftu {
            plan,
            ss0_fwd: get(ModuleKind::Superstep0, false)?,
            ss0_inv: get(ModuleKind::Superstep0, true)?,
            ss2_fwd: get(ModuleKind::Superstep2, false)?,
            ss2_inv: get(ModuleKind::Superstep2, true)?,
        })
    }

    fn dims_local(&self) -> Vec<i64> {
        self.plan.local_shape.iter().map(|&x| x as i64).collect()
    }

    /// Superstep 0 for one rank: returns the (p, packet_len) packet
    /// matrix as per-destination vectors.
    pub fn superstep0(&self, rank: usize, local: &[C64], dir: Direction) -> Result<Vec<Vec<C64>>> {
        let plan = &self.plan;
        let s_coords = plan.dist.proc_coords(rank);
        let tables = TwiddleTables::new(plan, &s_coords);
        // Table inputs are f32 vectors, in (re, im) pairs per axis. The
        // forward tables are passed even for the inverse module: the
        // module conjugates internally (aot.py lowers conj=inverse).
        let mut table_planes: Vec<(Vec<f32>, Vec<i64>)> = Vec::new();
        for t in &tables.per_axis {
            let (re, im) = split_planes(t);
            let len = t.len() as i64;
            table_planes.push((re, vec![len]));
            table_planes.push((im, vec![len]));
        }
        let module = match dir {
            Direction::Forward => &self.ss0_fwd,
            Direction::Inverse => &self.ss0_inv,
        };
        let dims = self.dims_local();
        let extra: Vec<(&[f32], &[i64])> =
            table_planes.iter().map(|(d, s)| (d.as_slice(), s.as_slice())).collect();
        let packets_flat = module.run_complex(local, &dims, &extra)?;
        let packet_len = plan.packet_len();
        Ok(packets_flat.chunks_exact(packet_len).map(|c| c.to_vec()).collect())
    }

    /// Superstep 2 for one rank.
    pub fn superstep2(&self, w: &[C64], dir: Direction) -> Result<Vec<C64>> {
        let module = match dir {
            Direction::Forward => &self.ss2_fwd,
            Direction::Inverse => &self.ss2_inv,
        };
        module.run_complex(w, &self.dims_local(), &[])
    }

    /// Full Algorithm 2.3 in sequential-SPMD over a scattered global
    /// array (test/demo entry; long-running services drive the
    /// supersteps rank-by-rank themselves).
    pub fn execute_global(&self, global: &[C64], dir: Direction) -> Result<Vec<C64>> {
        let plan = &self.plan;
        let p = plan.num_procs();
        let locals = plan.dist.scatter(global);
        // Superstep 0 on every rank.
        let mut all_packets: Vec<Vec<Vec<C64>>> = Vec::with_capacity(p);
        for (rank, local) in locals.iter().enumerate() {
            all_packets.push(self.superstep0(rank, local, dir)?);
        }
        // The all-to-all: transpose the packet matrix.
        let mut outputs = Vec::with_capacity(p);
        for rank in 0..p {
            let incoming: Vec<Vec<C64>> =
                (0..p).map(|src| std::mem::take(&mut all_packets[src][rank])).collect();
            let mut w = vec![C64::ZERO; plan.local_len()];
            unpack(plan, &incoming, &mut w);
            outputs.push(self.superstep2(&w, dir)?);
        }
        Ok(plan.dist.gather(&outputs))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::fft::{fftn_inplace, rel_l2_error};
    use crate::testing::Rng;

    fn artifacts_ready() -> bool {
        Path::new("artifacts/manifest.json").exists()
    }

    #[test]
    fn xla_engine_matches_native_2d() {
        if !artifacts_ready() {
            eprintln!("skipping: run `make artifacts` first");
            return;
        }
        let shape = [16usize, 16];
        let pgrid = [2usize, 2];
        let xla = XlaFftu::load(Path::new("artifacts"), &shape, &pgrid).unwrap();
        let mut rng = Rng::new(0xE0);
        let n = 256;
        let x: Vec<C64> =
            (0..n).map(|_| C64::new(rng.f64_signed(), rng.f64_signed())).collect();
        let got = xla.execute_global(&x, Direction::Forward).unwrap();
        let mut want = x.clone();
        fftn_inplace(&mut want, &shape, Direction::Forward);
        let err = rel_l2_error(&got, &want);
        assert!(err < 1e-4, "xla vs native rel err {err}");
    }

    #[test]
    fn xla_engine_matches_native_3d_and_roundtrips() {
        if !artifacts_ready() {
            eprintln!("skipping: run `make artifacts` first");
            return;
        }
        let shape = [32usize, 32, 32];
        let pgrid = [2usize, 2, 2];
        let xla = XlaFftu::load(Path::new("artifacts"), &shape, &pgrid).unwrap();
        let mut rng = Rng::new(0xE1);
        let n: usize = shape.iter().product();
        let x: Vec<C64> =
            (0..n).map(|_| C64::new(rng.f64_signed(), rng.f64_signed())).collect();
        let y = xla.execute_global(&x, Direction::Forward).unwrap();
        let mut want = x.clone();
        fftn_inplace(&mut want, &shape, Direction::Forward);
        assert!(rel_l2_error(&y, &want) < 1e-4);
        // Inverse through the _inv artifacts.
        let z = xla.execute_global(&y, Direction::Inverse).unwrap();
        let z: Vec<C64> = z.iter().map(|v| *v / n as f64).collect();
        assert!(rel_l2_error(&z, &x) < 1e-4);
    }
}
