//! Runtime: PJRT loading/execution of the AOT artifacts (L2/L1 bridge).
//!
//! Python runs once at build time (`make artifacts`); this module makes
//! the resulting HLO-text modules executable from the Rust request path.
//!
//! The PJRT engine itself needs the external `xla` and `anyhow` crates,
//! which the offline build does not carry: it is gated behind the
//! `xla-pjrt` cargo feature. Without the feature, [`XlaFftu`] is a stub
//! whose `load` reports the engine as unavailable, so every call site
//! (CLI selftest, integration tests) degrades to its skip path instead
//! of failing to compile.

pub mod json;
pub mod manifest;

#[cfg(feature = "xla-pjrt")]
pub mod engine;
#[cfg(feature = "xla-pjrt")]
pub mod xla_fftu;

#[cfg(not(feature = "xla-pjrt"))]
pub mod unavailable;

#[cfg(feature = "xla-pjrt")]
pub use engine::{join_planes, split_planes, XlaEngine, XlaModule};
pub use manifest::{Manifest, ModuleEntry, ModuleKind};
#[cfg(not(feature = "xla-pjrt"))]
pub use unavailable::XlaFftu;
#[cfg(feature = "xla-pjrt")]
pub use xla_fftu::XlaFftu;
