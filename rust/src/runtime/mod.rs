//! Runtime: PJRT loading/execution of the AOT artifacts (L2/L1 bridge).
//!
//! Python runs once at build time (`make artifacts`); this module makes
//! the resulting HLO-text modules executable from the Rust request path.

pub mod engine;
pub mod json;
pub mod manifest;
pub mod xla_fftu;

pub use engine::{join_planes, split_planes, XlaEngine, XlaModule};
pub use manifest::{Manifest, ModuleEntry, ModuleKind};
pub use xla_fftu::XlaFftu;
