//! Stub [`XlaFftu`] used when the crate is built without the `xla-pjrt`
//! feature (the default, dependency-free configuration): keeps every
//! call site compiling while reporting the engine as unavailable, so
//! selftests and integration tests take their skip paths.

use std::fmt;
use std::path::Path;

use crate::fft::{C64, Direction};

/// Error returned by the stub: this build has no PJRT engine.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct XlaUnavailable;

impl fmt::Display for XlaUnavailable {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(
            f,
            "XLA/PJRT engine unavailable: built without the `xla-pjrt` feature \
             (vendor the `xla` and `anyhow` crates, declare them in Cargo.toml, \
             then rebuild with `--features xla-pjrt`)"
        )
    }
}

impl std::error::Error for XlaUnavailable {}

/// Stand-in for the PJRT-backed FFTU executor.
#[derive(Debug)]
pub struct XlaFftu {
    _private: (),
}

impl XlaFftu {
    /// Always fails in this build; the real implementation loads the AOT
    /// artifacts from `artifacts/` and compiles them on the PJRT CPU
    /// client.
    pub fn load(
        _artifacts: &Path,
        _shape: &[usize],
        _pgrid: &[usize],
    ) -> Result<Self, XlaUnavailable> {
        Err(XlaUnavailable)
    }

    /// Unreachable in this build (`load` never succeeds); present so the
    /// call sites typecheck.
    pub fn execute_global(
        &self,
        _global: &[C64],
        _dir: Direction,
    ) -> Result<Vec<C64>, XlaUnavailable> {
        Err(XlaUnavailable)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stub_reports_unavailable() {
        let err = XlaFftu::load(Path::new("artifacts"), &[16, 16], &[2, 2]).unwrap_err();
        assert!(err.to_string().contains("xla-pjrt"));
        // The `{:#}` alternate form used by call sites also works.
        assert!(format!("{err:#}").contains("xla-pjrt"));
    }
}
