//! Artifact manifest: the contract between `python/compile/aot.py` and
//! the Rust runtime. Lists every AOT-lowered HLO module with its
//! signature (kind, shapes, processor grid, direction).

use std::path::{Path, PathBuf};

use super::json::Json;

/// Kind of an AOT module (mirrors `aot.py`).
#[derive(Clone, Copy, PartialEq, Eq, Debug)]
pub enum ModuleKind {
    /// Algorithm 2.3 superstep 0: fftn + Pallas twiddle + pack.
    Superstep0,
    /// Algorithm 2.3 superstep 2: strided F_p tensor transform.
    Superstep2,
    /// Plain local fftn (engine parity tests).
    Fftn,
    /// Standalone L1 Stockham kernel.
    Stockham,
}

#[derive(Clone, Debug)]
pub struct ModuleEntry {
    pub name: String,
    pub file: PathBuf,
    pub kind: ModuleKind,
    pub shape: Vec<usize>,
    pub pgrid: Vec<usize>,
    pub local: Vec<usize>,
    pub packet: Vec<usize>,
    pub p: usize,
    pub inverse: bool,
}

#[derive(Clone, Debug)]
pub struct Manifest {
    pub dir: PathBuf,
    pub modules: Vec<ModuleEntry>,
}

impl Manifest {
    /// Load `<dir>/manifest.json`.
    pub fn load(dir: &Path) -> Result<Manifest, String> {
        let path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&path)
            .map_err(|e| format!("cannot read {}: {e} (run `make artifacts`)", path.display()))?;
        let v = Json::parse(&text).map_err(|e| format!("{}: {e}", path.display()))?;
        let mods = v
            .get("modules")
            .and_then(|m| m.as_arr())
            .ok_or("manifest missing `modules` array")?;
        let mut modules = Vec::with_capacity(mods.len());
        for m in mods {
            let name = m
                .get("name")
                .and_then(|x| x.as_str())
                .ok_or("module missing name")?
                .to_string();
            let kind = match m.get("kind").and_then(|x| x.as_str()) {
                Some("superstep0") => ModuleKind::Superstep0,
                Some("superstep2") => ModuleKind::Superstep2,
                Some("fftn") => ModuleKind::Fftn,
                Some("stockham") => ModuleKind::Stockham,
                other => return Err(format!("module {name}: unknown kind {other:?}")),
            };
            let usize_vec =
                |key: &str| m.get(key).and_then(|x| x.as_usize_vec()).unwrap_or_default();
            modules.push(ModuleEntry {
                file: dir.join(
                    m.get("file").and_then(|x| x.as_str()).ok_or("module missing file")?,
                ),
                kind,
                shape: usize_vec("shape"),
                pgrid: usize_vec("pgrid"),
                local: usize_vec("local"),
                packet: usize_vec("packet"),
                p: m.get("p").and_then(|x| x.as_usize()).unwrap_or(1),
                inverse: m.get("inverse").and_then(|x| x.as_bool()).unwrap_or(false),
                name,
            });
        }
        Ok(Manifest { dir: dir.to_path_buf(), modules })
    }

    /// Find the module for (kind, shape, pgrid, inverse).
    pub fn find(
        &self,
        kind: ModuleKind,
        shape: &[usize],
        pgrid: &[usize],
        inverse: bool,
    ) -> Option<&ModuleEntry> {
        self.modules.iter().find(|m| {
            m.kind == kind
                && m.shape == shape
                && (m.pgrid == pgrid || matches!(kind, ModuleKind::Fftn | ModuleKind::Stockham))
                && m.inverse == inverse
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn loads_real_manifest_if_present() {
        // Integration-level check, skipped when artifacts are not built.
        let dir = Path::new("artifacts");
        if !dir.join("manifest.json").exists() {
            eprintln!("skipping: run `make artifacts` first");
            return;
        }
        let m = Manifest::load(dir).unwrap();
        assert!(!m.modules.is_empty());
        // Every referenced file must exist.
        for e in &m.modules {
            assert!(e.file.exists(), "missing {}", e.file.display());
        }
        // The quickstart config must be present in both directions.
        for inv in [false, true] {
            assert!(
                m.find(ModuleKind::Superstep0, &[32, 32, 32], &[2, 2, 2], inv).is_some(),
                "missing ss0 inv={inv}"
            );
            assert!(
                m.find(ModuleKind::Superstep2, &[32, 32, 32], &[2, 2, 2], inv).is_some(),
                "missing ss2 inv={inv}"
            );
        }
    }
}
