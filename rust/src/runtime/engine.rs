//! PJRT execution engine: load HLO-text artifacts, compile once on the
//! CPU PJRT client, execute with split re/im f32 literals.
//!
//! Complex interchange convention (see DESIGN.md §2): every module takes
//! and returns *pairs* of f32 arrays (re, im); complex is reconstructed
//! with `lax.complex` inside the lowered module. The engine converts
//! between the library's `C64` (f64) and the artifact's f32 planes at
//! the boundary.

use std::path::Path;

use anyhow::{Context, Result};

use crate::fft::C64;

/// A compiled AOT module ready to execute.
pub struct XlaModule {
    pub name: String,
    exe: xla::PjRtLoadedExecutable,
    n_outputs: usize,
}

impl std::fmt::Debug for XlaModule {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("XlaModule")
            .field("name", &self.name)
            .field("n_outputs", &self.n_outputs)
            .finish_non_exhaustive()
    }
}

/// Shared PJRT CPU client. One per process; executables keep it alive.
pub struct XlaEngine {
    client: xla::PjRtClient,
}

impl std::fmt::Debug for XlaEngine {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("XlaEngine").finish_non_exhaustive()
    }
}

impl XlaEngine {
    pub fn cpu() -> Result<Self> {
        Ok(XlaEngine { client: xla::PjRtClient::cpu().context("creating PJRT CPU client")? })
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Load and compile an HLO-text artifact.
    pub fn load(&self, path: &Path, name: &str, n_outputs: usize) -> Result<XlaModule> {
        let proto = xla::HloModuleProto::from_text_file(
            path.to_str().context("non-utf8 artifact path")?,
        )
        .with_context(|| format!("parsing HLO text {}", path.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .with_context(|| format!("compiling {}", path.display()))?;
        Ok(XlaModule { name: name.to_string(), exe, n_outputs })
    }
}

impl XlaModule {
    /// Execute with f32 inputs (each a flat buffer + dims); returns the
    /// flat f32 outputs in module order.
    pub fn run_f32(&self, inputs: &[(&[f32], &[i64])]) -> Result<Vec<Vec<f32>>> {
        let literals: Vec<xla::Literal> = inputs
            .iter()
            .map(|(data, dims)| {
                xla::Literal::vec1(data)
                    .reshape(dims)
                    .with_context(|| format!("reshaping input for {}", self.name))
            })
            .collect::<Result<_>>()?;
        let result = self.exe.execute::<xla::Literal>(&literals)?[0][0]
            .to_literal_sync()
            .with_context(|| format!("fetching result of {}", self.name))?;
        // aot.py lowers with return_tuple=True.
        let parts = result.to_tuple().context("untupling result")?;
        anyhow::ensure!(
            parts.len() == self.n_outputs,
            "{}: expected {} outputs, got {}",
            self.name,
            self.n_outputs,
            parts.len()
        );
        parts.into_iter().map(|l| l.to_vec::<f32>().map_err(Into::into)).collect()
    }

    /// Execute a (re, im) -> (re, im) module on complex data: splits the
    /// C64 buffer into f32 planes, runs, and re-joins.
    pub fn run_complex(&self, data: &[C64], dims: &[i64], extra: &[(&[f32], &[i64])]) -> Result<Vec<C64>> {
        let (re, im) = split_planes(data);
        let mut inputs: Vec<(&[f32], &[i64])> = vec![(&re, dims), (&im, dims)];
        inputs.extend_from_slice(extra);
        let out = self.run_f32(&inputs)?;
        anyhow::ensure!(out.len() == 2, "{}: expected re/im outputs", self.name);
        Ok(join_planes(&out[0], &out[1]))
    }
}

/// C64 slice -> (re, im) f32 planes.
pub fn split_planes(data: &[C64]) -> (Vec<f32>, Vec<f32>) {
    let mut re = Vec::with_capacity(data.len());
    let mut im = Vec::with_capacity(data.len());
    for v in data {
        re.push(v.re as f32);
        im.push(v.im as f32);
    }
    (re, im)
}

/// (re, im) f32 planes -> C64 buffer.
pub fn join_planes(re: &[f32], im: &[f32]) -> Vec<C64> {
    assert_eq!(re.len(), im.len());
    re.iter().zip(im).map(|(&r, &i)| C64::new(r as f64, i as f64)).collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn split_join_roundtrip() {
        let data: Vec<C64> = (0..10).map(|i| C64::new(i as f64, -(i as f64))).collect();
        let (re, im) = split_planes(&data);
        let back = join_planes(&re, &im);
        assert_eq!(back, data);
    }

    #[test]
    fn engine_runs_fftn_artifact() {
        let dir = Path::new("artifacts");
        if !dir.join("fftn_16x16.hlo.txt").exists() {
            eprintln!("skipping: run `make artifacts` first");
            return;
        }
        let engine = XlaEngine::cpu().unwrap();
        let module = engine.load(&dir.join("fftn_16x16.hlo.txt"), "fftn_16x16", 2).unwrap();
        // FFT of a delta is all-ones.
        let mut x = vec![C64::ZERO; 256];
        x[0] = C64::ONE;
        let y = module.run_complex(&x, &[16, 16], &[]).unwrap();
        for v in &y {
            assert!((v.re - 1.0).abs() < 1e-4 && v.im.abs() < 1e-4, "{v:?}");
        }
    }
}
