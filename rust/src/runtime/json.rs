//! Minimal JSON parser for the artifact manifest.
//!
//! The offline vendor set has no `serde_json`, and the manifest schema is
//! small and fully under our control, so a compact recursive-descent
//! parser is the right tool. Supports the full JSON value grammar minus
//! exotic number forms (enough for `manifest.json`, checked by tests).

use std::collections::BTreeMap;
use std::fmt;

#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    pub fn parse(text: &str) -> Result<Json, JsonError> {
        let mut p = Parser { bytes: text.as_bytes(), pos: 0 };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.pos != p.bytes.len() {
            return Err(p.err("trailing characters"));
        }
        Ok(v)
    }

    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(x) => Some(*x),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|x| x as usize)
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(v) => Some(v),
            _ => None,
        }
    }

    /// Convenience: `[1, 2, 3]` -> `vec![1, 2, 3]`.
    pub fn as_usize_vec(&self) -> Option<Vec<usize>> {
        self.as_arr()?.iter().map(|v| v.as_usize()).collect()
    }
}

#[derive(Debug)]
pub struct JsonError {
    pub pos: usize,
    pub msg: String,
}

impl fmt::Display for JsonError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "JSON error at byte {}: {}", self.pos, self.msg)
    }
}

impl std::error::Error for JsonError {}

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> JsonError {
        JsonError { pos: self.pos, msg: msg.to_string() }
    }

    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn bump(&mut self) -> Option<u8> {
        let b = self.peek();
        if b.is_some() {
            self.pos += 1;
        }
        b
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.pos += 1;
        }
    }

    fn expect(&mut self, b: u8) -> Result<(), JsonError> {
        if self.bump() == Some(b) {
            Ok(())
        } else {
            Err(self.err(&format!("expected '{}'", b as char)))
        }
    }

    fn literal(&mut self, word: &str, v: Json) -> Result<Json, JsonError> {
        if self.bytes[self.pos..].starts_with(word.as_bytes()) {
            self.pos += word.len();
            Ok(v)
        } else {
            Err(self.err(&format!("expected `{word}`")))
        }
    }

    fn value(&mut self) -> Result<Json, JsonError> {
        self.skip_ws();
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.literal("true", Json::Bool(true)),
            Some(b'f') => self.literal("false", Json::Bool(false)),
            Some(b'n') => self.literal("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(self.err("unexpected character")),
        }
    }

    fn object(&mut self) -> Result<Json, JsonError> {
        self.expect(b'{')?;
        let mut map = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(map));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            let val = self.value()?;
            map.insert(key, val);
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b'}') => return Ok(Json::Obj(map)),
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }

    fn array(&mut self) -> Result<Json, JsonError> {
        self.expect(b'[')?;
        let mut out = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(out));
        }
        loop {
            out.push(self.value()?);
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b']') => return Ok(Json::Arr(out)),
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }

    fn string(&mut self) -> Result<String, JsonError> {
        self.expect(b'"')?;
        let mut s = String::new();
        loop {
            match self.bump() {
                Some(b'"') => return Ok(s),
                Some(b'\\') => match self.bump() {
                    Some(b'"') => s.push('"'),
                    Some(b'\\') => s.push('\\'),
                    Some(b'/') => s.push('/'),
                    Some(b'n') => s.push('\n'),
                    Some(b't') => s.push('\t'),
                    Some(b'r') => s.push('\r'),
                    Some(b'b') => s.push('\u{8}'),
                    Some(b'f') => s.push('\u{c}'),
                    Some(b'u') => {
                        let mut code = 0u32;
                        for _ in 0..4 {
                            let c = self.bump().ok_or_else(|| self.err("bad \\u"))?;
                            code = code * 16
                                + (c as char).to_digit(16).ok_or_else(|| self.err("bad hex"))?;
                        }
                        s.push(char::from_u32(code).unwrap_or('\u{fffd}'));
                    }
                    _ => return Err(self.err("bad escape")),
                },
                Some(c) => s.push(c as char),
                None => return Err(self.err("unterminated string")),
            }
        }
    }

    fn number(&mut self) -> Result<Json, JsonError> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit() || matches!(c, b'.' | b'e' | b'E' | b'+' | b'-'))
        {
            self.pos += 1;
        }
        let text = std::str::from_utf8(&self.bytes[start..self.pos]).unwrap();
        text.parse::<f64>().map(Json::Num).map_err(|_| self.err("bad number"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_manifest_shape() {
        let text = r#"{
            "source_digest": "abc",
            "modules": [
                {"name": "m1", "shape": [16, 16], "p": 4, "inverse": false},
                {"name": "m2", "shape": [8], "p": 1, "inverse": true}
            ]
        }"#;
        let v = Json::parse(text).unwrap();
        assert_eq!(v.get("source_digest").unwrap().as_str(), Some("abc"));
        let mods = v.get("modules").unwrap().as_arr().unwrap();
        assert_eq!(mods.len(), 2);
        assert_eq!(mods[0].get("shape").unwrap().as_usize_vec(), Some(vec![16, 16]));
        assert_eq!(mods[1].get("inverse").unwrap().as_bool(), Some(true));
    }

    #[test]
    fn parses_scalars_and_nesting() {
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(Json::parse("-3.5e2").unwrap().as_f64(), Some(-350.0));
        assert_eq!(Json::parse(r#""a\nb""#).unwrap().as_str(), Some("a\nb"));
        let v = Json::parse(r#"[[1,2],[3]]"#).unwrap();
        assert_eq!(v.as_arr().unwrap()[0].as_usize_vec(), Some(vec![1, 2]));
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("12 34").is_err());
        assert!(Json::parse("'single'").is_err());
    }

    #[test]
    fn unicode_escape() {
        assert_eq!(Json::parse(r#""A""#).unwrap().as_str(), Some("A"));
    }
}
