//! Exhaustive interleaving exploration of the BSP mailbox protocol.
//!
//! The dependency-free, always-on companion to the `cfg(loom)` models in
//! `bsp/machine.rs`: a small abstract machine whose operations mirror
//! what `Ctx::exchange_swap` / `pairwise_exchange` do to the shared
//! mailbox (`slots[sender * p + receiver]`) and what the arena drivers
//! do with the session try-lock — then a depth-first search over EVERY
//! interleaving of the per-process programs, checking the protocol's
//! safety invariants in each one:
//!
//! - a deposit never lands in an occupied slot (the data race the
//!   two-barrier handshake exists to prevent — without the second
//!   barrier, round `r + 1`'s deposit can clobber an uncollected round-`r`
//!   packet),
//! - a collect always finds a packet, and from the right round,
//! - the machine never deadlocks (some process can always step),
//! - the session try-lock admits at most one holder and never blocks
//!   (losers fall back, they don't wait), and
//! - a [`Op::Panic`] aborts the session through the cancellable
//!   barrier: every parked waiter is released and unwinds, every later
//!   barrier arrival unwinds immediately, and no interleaving of the
//!   fault strands a peer (the deadlock the pre-abort `std::sync::Barrier`
//!   runtime exhibited — kept reproducible here by modeling the panic as
//!   a truncated program instead).
//!
//! The search memoizes visited states, so equivalent interleavings are
//! explored once and the whole space of a few processes with a few ops
//! each stays exact *and* small. Tests prove the checker is *live* by
//! feeding it a faulty single-barrier variant of the exchange and
//! asserting it reports the clobber.

use std::collections::HashSet;

/// One abstract operation of a modeled process.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Op {
    /// Deposit this round's packet into the mailbox slot `(self, to)`.
    Deposit { to: usize },
    /// Take the packet `from` deposited for this process.
    Collect { from: usize },
    /// Block until every process has arrived.
    Barrier,
    /// Try to acquire the shared session lock; on failure record the
    /// fallback and continue — never blocks (the `ExecArena` discipline).
    TrySession,
    /// Release the session lock if this process holds it.
    EndSession,
    /// Panic: abort the session (the cancellable-barrier discipline).
    /// This process unwinds; every process parked at the barrier is
    /// released and unwinds; every later barrier arrival unwinds
    /// immediately instead of waiting for a rendezvous that can no
    /// longer complete.
    Panic,
}

/// A safety violation, with the interleaving (sequence of process ids
/// that stepped) that produced it.
#[derive(Clone, Debug)]
pub struct Violation {
    pub interleaving: Vec<usize>,
    pub reason: String,
}

/// Aggregate facts about the exhaustive search (states are deduplicated,
/// so each count is over *distinct* reachable states).
#[derive(Clone, Copy, Debug, Default)]
pub struct ExploreStats {
    /// Distinct terminal states reached (every process ran to the end).
    pub terminal_states: usize,
    /// Terminal states in which at least one process lost the session
    /// try-lock and fell back.
    pub fallbacks: usize,
    /// Terminal states in which every `TrySession` succeeded.
    pub all_acquired: usize,
    /// Terminal states reached through a session abort ([`Op::Panic`]):
    /// every process still terminated — abort releases, never strands.
    pub aborts: usize,
}

#[derive(Clone, PartialEq, Eq, Hash)]
struct State {
    pc: Vec<usize>,
    /// `slots[s * p + t]`: the round tag of an uncollected packet from
    /// `s` to `t`, if any.
    slots: Vec<Option<u32>>,
    /// Barrier arrival flags; when all processes have arrived, everyone
    /// advances past the barrier at once.
    arrived: Vec<bool>,
    /// Per-process count of deposits performed (the round tag).
    deposit_round: Vec<u32>,
    /// Per-(receiver, sender) count of collects performed.
    collect_round: Vec<u32>,
    session_holder: Option<usize>,
    fell_back: bool,
    /// The cancellable barrier's abort flag: set by [`Op::Panic`],
    /// permanent for the rest of the session.
    aborted: bool,
}

/// Explore every interleaving of `programs` (one op sequence per
/// process). Returns aggregate stats, or the first violation found.
pub fn explore(programs: &[Vec<Op>]) -> Result<ExploreStats, Violation> {
    let p = programs.len();
    let state = State {
        pc: vec![0; p],
        slots: vec![None; p * p],
        arrived: vec![false; p],
        deposit_round: vec![0; p],
        collect_round: vec![0; p * p],
        session_holder: None,
        fell_back: false,
        aborted: false,
    };
    let mut stats = ExploreStats::default();
    let mut trail = Vec::new();
    let mut visited = HashSet::new();
    dfs(programs, &state, &mut trail, &mut stats, &mut visited)?;
    Ok(stats)
}

fn dfs(
    programs: &[Vec<Op>],
    state: &State,
    trail: &mut Vec<usize>,
    stats: &mut ExploreStats,
    visited: &mut HashSet<State>,
) -> Result<(), Violation> {
    if !visited.insert(state.clone()) {
        return Ok(());
    }
    let p = programs.len();
    // A process is enabled if it has ops left and is not parked at a
    // barrier it already arrived at.
    let enabled: Vec<usize> = (0..p)
        .filter(|&i| state.pc[i] < programs[i].len() && !state.arrived[i])
        .collect();
    if enabled.is_empty() {
        let unfinished: Vec<usize> =
            (0..p).filter(|&i| state.pc[i] < programs[i].len()).collect();
        if unfinished.is_empty() {
            stats.terminal_states += 1;
            if state.fell_back {
                stats.fallbacks += 1;
            } else {
                stats.all_acquired += 1;
            }
            if state.aborted {
                stats.aborts += 1;
            }
            return Ok(());
        }
        return Err(Violation {
            interleaving: trail.clone(),
            reason: format!("deadlock: processes {unfinished:?} are blocked forever"),
        });
    }
    for &i in &enabled {
        let mut next = state.clone();
        trail.push(i);
        let op = programs[i][next.pc[i]];
        let fault = step(&mut next, i, op, programs);
        if let Some(reason) = fault {
            let v = Violation { interleaving: trail.clone(), reason };
            trail.pop();
            return Err(v);
        }
        dfs(programs, &next, trail, stats, visited)?;
        trail.pop();
    }
    Ok(())
}

/// Unwind process `j` out of an aborted session: it abandons its
/// remaining program (mirrors `abort_unwind` in `bsp/machine.rs`).
fn unwind(state: &mut State, j: usize, programs: &[Vec<Op>]) {
    state.arrived[j] = false;
    state.pc[j] = programs[j].len();
}

/// Apply `op` for process `i`; returns a violation reason on fault.
fn step(state: &mut State, i: usize, op: Op, programs: &[Vec<Op>]) -> Option<String> {
    let p = programs.len();
    match op {
        Op::Deposit { to } => {
            let slot = i * p + to;
            if state.slots[slot].is_some() {
                return Some(format!(
                    "process {i} deposits into slot ({i} -> {to}) while round \
                     {}'s packet is still uncollected",
                    state.slots[slot].unwrap()
                ));
            }
            state.slots[slot] = Some(state.deposit_round[i]);
            state.deposit_round[i] += 1;
            state.pc[i] += 1;
        }
        Op::Collect { from } => {
            let slot = from * p + i;
            match state.slots[slot].take() {
                None => {
                    return Some(format!(
                        "process {i} collects from slot ({from} -> {i}) before \
                         anything was deposited"
                    ));
                }
                Some(tag) => {
                    let want = state.collect_round[i * p + from];
                    if tag != want {
                        return Some(format!(
                            "process {i} collected round {tag} from {from}, \
                             expected round {want}"
                        ));
                    }
                    state.collect_round[i * p + from] += 1;
                }
            }
            state.pc[i] += 1;
        }
        Op::Barrier => {
            if state.aborted {
                // The cancellable barrier returns `Err(Aborted)`
                // immediately; the arrival unwinds instead of waiting.
                unwind(state, i, programs);
                return None;
            }
            state.arrived[i] = true;
            if state.arrived.iter().all(|&a| a) {
                for j in 0..state.pc.len() {
                    state.arrived[j] = false;
                    state.pc[j] += 1;
                }
            }
        }
        Op::TrySession => {
            if state.session_holder.is_none() {
                state.session_holder = Some(i);
            } else {
                state.fell_back = true;
            }
            state.pc[i] += 1;
        }
        Op::EndSession => {
            if state.session_holder == Some(i) {
                state.session_holder = None;
            }
            state.pc[i] += 1;
        }
        Op::Panic => {
            // Abort + notify_all: the panicking process unwinds, and so
            // does every process currently parked at the barrier.
            state.aborted = true;
            unwind(state, i, programs);
            for j in 0..p {
                if state.arrived[j] {
                    unwind(state, j, programs);
                }
            }
        }
    }
    None
}

/// The real two-barrier exchange, `rounds` times: everyone deposits to
/// everyone else, barrier, everyone collects, barrier.
pub fn two_barrier_exchange(p: usize, rounds: usize) -> Vec<Vec<Op>> {
    (0..p)
        .map(|i| {
            let mut ops = Vec::new();
            for _ in 0..rounds {
                for t in (0..p).filter(|&t| t != i) {
                    ops.push(Op::Deposit { to: t });
                }
                ops.push(Op::Barrier);
                for f in (0..p).filter(|&f| f != i) {
                    ops.push(Op::Collect { from: f });
                }
                ops.push(Op::Barrier);
            }
            ops
        })
        .collect()
}

/// The depth-2 split-phase pipelined batch exchange, as the mailbox
/// sees it (mirrors the `exchange_start`/`exchange_finish` sequencing
/// of the pipelined batch drivers in `fftu/mod.rs`): entry 0's
/// `exchange_start` deposits up front; each loop iteration packs the
/// next entry into the alternate buffer set (local work, invisible
/// here), finishes the in-flight entry (rendezvous barrier, collect,
/// drain barrier), and only *then* starts the next one. The drain
/// barrier before the next deposit is exactly what makes double
/// buffering safe with single-buffered mailbox slots — one entry in
/// flight at a time.
pub fn split_phase_pipeline(p: usize, entries: usize) -> Vec<Vec<Op>> {
    (0..p)
        .map(|i| {
            let mut ops = Vec::new();
            let deposit_all = |ops: &mut Vec<Op>| {
                for t in (0..p).filter(|&t| t != i) {
                    ops.push(Op::Deposit { to: t });
                }
            };
            // exchange_start(0): entry 0's packets enter the mailbox.
            deposit_all(&mut ops);
            for e in 0..entries {
                // exchange_finish(e): rendezvous, drain, drain barrier.
                ops.push(Op::Barrier);
                for f in (0..p).filter(|&f| f != i) {
                    ops.push(Op::Collect { from: f });
                }
                ops.push(Op::Barrier);
                // exchange_start(e + 1): only after the drain barrier.
                if e + 1 < entries {
                    deposit_all(&mut ops);
                }
            }
            ops
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn two_barrier_protocol_is_race_free() {
        for (p, rounds) in [(2, 2), (3, 2)] {
            let stats = explore(&two_barrier_exchange(p, rounds))
                .expect("the executed protocol must pass every interleaving");
            assert_eq!(stats.terminal_states, 1, "p={p}: one clean terminal state");
        }
    }

    /// The pipelined split-phase protocol: per-entry deposits are
    /// deferred to after the previous entry's drain barrier, so every
    /// interleaving is race-free even though two entries' buffers are
    /// live at once.
    #[test]
    fn pipelined_split_phase_protocol_is_race_free() {
        for (p, entries) in [(2, 3), (3, 2)] {
            let stats = explore(&split_phase_pipeline(p, entries))
                .expect("the pipelined protocol must pass every interleaving");
            assert_eq!(stats.terminal_states, 1, "p={p}: one clean terminal state");
        }
    }

    /// Starting entry `e + 1`'s exchange before finishing entry `e`
    /// (overlapping two exchanges in the mailbox — exactly what the
    /// static split-phase lint forbids): the second deposit clobbers the
    /// uncollected first packet. The checker must find it, proving the
    /// drain-barrier placement in the pipelined drivers is load-bearing.
    #[test]
    fn eager_start_before_finish_is_caught() {
        let p = 2;
        let faulty: Vec<Vec<Op>> = (0..p)
            .map(|i| {
                vec![
                    Op::Deposit { to: 1 - i }, // exchange_start(0)
                    Op::Deposit { to: 1 - i }, // exchange_start(1) — too early
                    Op::Barrier,
                    Op::Collect { from: 1 - i },
                    Op::Barrier,
                    Op::Barrier,
                    Op::Collect { from: 1 - i },
                    Op::Barrier,
                ]
            })
            .collect();
        let v = explore(&faulty).expect_err("eager start must be detected");
        assert!(v.reason.contains("uncollected"), "{}", v.reason);
    }

    /// Drop the second barrier (the one between collect and the next
    /// round's deposit): some interleaving lets a fast process clobber a
    /// packet its slow peer has not collected yet. The checker must find
    /// it — this proves the checker itself is live.
    #[test]
    fn single_barrier_variant_is_caught() {
        let p = 2;
        let faulty: Vec<Vec<Op>> = (0..p)
            .map(|i| {
                let mut ops = Vec::new();
                for _ in 0..2 {
                    ops.push(Op::Deposit { to: 1 - i });
                    ops.push(Op::Barrier);
                    ops.push(Op::Collect { from: 1 - i });
                    // second barrier dropped
                }
                ops
            })
            .collect();
        let v = explore(&faulty).expect_err("missing barrier must be detected");
        assert!(
            v.reason.contains("uncollected") || v.reason.contains("round"),
            "unexpected reason: {}",
            v.reason
        );
    }

    /// Drop the first barrier instead: a collect can run before the
    /// partner deposited (the `pairwise_exchange` expect-path).
    #[test]
    fn collect_before_deposit_is_caught() {
        let p = 2;
        let faulty: Vec<Vec<Op>> = (0..p)
            .map(|i| {
                vec![
                    Op::Deposit { to: 1 - i },
                    // first barrier dropped
                    Op::Collect { from: 1 - i },
                    Op::Barrier,
                ]
            })
            .collect();
        let v = explore(&faulty).expect_err("missing handshake must be detected");
        assert!(v.reason.contains("before anything was deposited"), "{}", v.reason);
    }

    /// The arena session try-lock: two drivers race for the same arena.
    /// No interleaving blocks, at most one holds, and both outcomes
    /// (contention fallback, sequential all-acquire) are reachable.
    #[test]
    fn try_lock_fallback_never_blocks() {
        let programs: Vec<Vec<Op>> = (0..2)
            .map(|i: usize| {
                vec![
                    Op::TrySession,
                    Op::Deposit { to: 1 - i },
                    Op::Barrier,
                    Op::Collect { from: 1 - i },
                    Op::Barrier,
                    Op::EndSession,
                ]
            })
            .collect();
        let stats = explore(&programs).expect("try-lock discipline must never deadlock");
        assert!(stats.fallbacks > 0, "some interleaving must hit the fallback");
        assert!(stats.all_acquired > 0, "some interleaving must avoid contention");
    }

    /// A barrier count mismatch (one process runs one fewer barrier) is
    /// a deadlock, and the checker says so.
    #[test]
    fn mismatched_barrier_counts_deadlock() {
        let programs = vec![vec![Op::Barrier, Op::Barrier], vec![Op::Barrier]];
        let v = explore(&programs).expect_err("stranded barrier must be detected");
        assert!(v.reason.contains("deadlock"), "{}", v.reason);
    }

    /// A mid-exchange panic under the cancellable barrier: every
    /// interleaving terminates (abort releases the waiters), and every
    /// abort path is actually reached.
    #[test]
    fn panic_aborts_without_stranding_any_peer() {
        for p in [2usize, 3] {
            let programs: Vec<Vec<Op>> = (0..p)
                .map(|i| {
                    let mut ops = Vec::new();
                    for t in (0..p).filter(|&t| t != i) {
                        ops.push(Op::Deposit { to: t });
                    }
                    if i == p - 1 {
                        // The last rank dies between deposit and the
                        // rendezvous — the worst spot for its peers.
                        ops.push(Op::Panic);
                        return ops;
                    }
                    ops.push(Op::Barrier);
                    for f in (0..p).filter(|&f| f != i) {
                        ops.push(Op::Collect { from: f });
                    }
                    ops.push(Op::Barrier);
                    ops
                })
                .collect();
            let stats = explore(&programs)
                .expect("a panic under the cancellable barrier must never deadlock");
            assert!(stats.terminal_states > 0, "p={p}");
            assert_eq!(stats.aborts, stats.terminal_states, "p={p}: every run aborts");
        }
    }

    /// The same fault under the pre-abort runtime (a bare
    /// `std::sync::Barrier`, modeled by the panicking rank simply never
    /// arriving) strands its peers — the deadlock this PR removes, kept
    /// reproducible to prove the abort semantics are load-bearing.
    #[test]
    fn panic_without_abort_semantics_is_the_old_deadlock() {
        let programs = vec![
            vec![Op::Deposit { to: 1 }, Op::Barrier, Op::Collect { from: 1 }, Op::Barrier],
            vec![Op::Deposit { to: 0 }], // dies; no abort, no arrival
        ];
        let v = explore(&programs).expect_err("bare-barrier panic must deadlock");
        assert!(v.reason.contains("deadlock"), "{}", v.reason);
    }

    /// A panic landing after the rendezvous: collectors that already
    /// passed the barrier finish their collects normally; everyone still
    /// terminates and the second barrier releases via the abort.
    #[test]
    fn panic_after_rendezvous_lets_collectors_finish() {
        let p = 2;
        let programs: Vec<Vec<Op>> = (0..p)
            .map(|i| {
                if i == 1 {
                    vec![Op::Deposit { to: 0 }, Op::Barrier, Op::Panic]
                } else {
                    vec![
                        Op::Deposit { to: 1 },
                        Op::Barrier,
                        Op::Collect { from: 1 },
                        Op::Barrier,
                    ]
                }
            })
            .collect();
        let stats = explore(&programs).expect("post-rendezvous panic must never deadlock");
        assert_eq!(stats.aborts, stats.terminal_states);
    }
}
