//! Static BSP protocol verifier: data-independent communication
//! schedules extracted from compiled plans, checked by a lint suite.
//!
//! The paper's headline guarantees — ONE all-to-all communication
//! superstep (Alg. 3.1), start-and-end in the same distribution, and
//! `h <= N/p` (Thm 2.1) — were previously enforced only dynamically, by
//! executing plans and comparing ledgers. This module turns them into
//! *static* properties: every compiled plan yields a per-rank sequence
//! of typed superstep [`Event`]s (a [`Schedule`]) recorded through a
//! [`RecordingCtx`] that mirrors [`crate::bsp::Ctx`]'s call surface but
//! touches no payload — extraction is `O(d · p)` per rank, like
//! [`crate::dist::analytic_h`]. The schedule is then checked by
//! [`verify`] against six lints (MPI-style collective matching and
//! friends, plus the split-phase pairing discipline of the pipelined
//! batch drivers, [`Lint`]) and against the analytic cost model
//! ([`crate::costmodel`]) superstep-for-superstep.
//!
//! Surfaces: [`crate::api::PlannedFft::analyze`] on the facade,
//! `cli analyze` for any (algorithm, kind, dist, grid), and the
//! `rust/tests/analysis.rs` sweep plus seeded-mutation tests proving
//! each lint fires. The dynamic checkers the schedule cannot cover live
//! in [`interleave`] (exhaustive in-repo interleaving exploration of the
//! mailbox protocol) and the `cfg(loom)` models in `bsp/machine.rs`.

pub mod extract;
pub mod interleave;

use std::fmt::Write as _;

use crate::bsp::{CostReport, SuperstepKind};

/// Session label of the FFTU execution arena
/// ([`crate::fftu::ExecArena`]).
pub const EXEC_ARENA: &str = "fftu-exec-arena";

/// Session label of the baselines' scratch arena
/// (`crate::baselines::ScratchArena`).
pub const SCRATCH_ARENA: &str = "baseline-scratch-arena";

/// One typed superstep event in a rank's data-independent schedule.
///
/// `Compute`/`AllToAll`/`Pairwise` mirror the three ways executors talk
/// to [`crate::bsp::Ctx`] (`begin_comp`, `exchange`/`exchange_swap`,
/// `pairwise_exchange`); `Barrier` models a bare synchronization; the
/// `Session*` markers model arena leases ([`crate::fftu::ExecArena`] /
/// the baselines' scratch arena), which the session-safety lint checks.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum Event {
    /// Computation superstep.
    Compute { label: &'static str },
    /// Collective all-to-all: `send_counts[t]` is the exact number of
    /// words this rank routes to rank `t` (the self entry is carried for
    /// completeness; the exchange never charges it and neither do the
    /// lints).
    AllToAll { label: &'static str, send_counts: Vec<usize> },
    /// Pairwise exchange with `partner`; `words` is what this rank
    /// sends (0 for a self-paired rank, which synchronizes only).
    Pairwise { label: &'static str, partner: usize, words: usize },
    /// Split-phase all-to-all, start half (`Ctx::exchange_start`): the
    /// packets are deposited into the mailbox now, but the communication
    /// superstep is *charged* at the matching [`Event::ExchangeFinish`],
    /// where [`verify`]'s normalization places the fused collective.
    ExchangeStart { label: &'static str, send_counts: Vec<usize> },
    /// Split-phase all-to-all, finish half (`Ctx::exchange_finish`):
    /// barrier, collect, charge. Pairs with the in-flight
    /// [`Event::ExchangeStart`] of the same label.
    ExchangeFinish { label: &'static str },
    /// Barrier-only synchronization superstep.
    Barrier { label: &'static str },
    /// This rank's driver leased the named arena.
    SessionBegin { arena: &'static str },
    /// The lease on the named arena was released.
    SessionEnd { arena: &'static str },
}

impl Event {
    /// The event's ledger label (arena name for the session markers).
    pub fn label(&self) -> &'static str {
        match self {
            Event::Compute { label }
            | Event::AllToAll { label, .. }
            | Event::Pairwise { label, .. }
            | Event::ExchangeStart { label, .. }
            | Event::ExchangeFinish { label }
            | Event::Barrier { label } => label,
            Event::SessionBegin { arena } | Event::SessionEnd { arena } => arena,
        }
    }

    /// Short kind tag used in rendered tables and lint messages.
    pub fn kind_name(&self) -> &'static str {
        match self {
            Event::Compute { .. } => "compute",
            Event::AllToAll { .. } => "all-to-all",
            Event::Pairwise { .. } => "pairwise",
            Event::ExchangeStart { .. } => "a2a-start",
            Event::ExchangeFinish { .. } => "a2a-finish",
            Event::Barrier { .. } => "barrier",
            Event::SessionBegin { .. } => "session+",
            Event::SessionEnd { .. } => "session-",
        }
    }

    /// True for the event kinds that move payload between ranks. The
    /// split-phase *start* counts (it deposits the packets); the finish
    /// does not — after normalization the fused collective sits at the
    /// finish position instead.
    pub fn is_comm(&self) -> bool {
        matches!(
            self,
            Event::AllToAll { .. } | Event::Pairwise { .. } | Event::ExchangeStart { .. }
        )
    }

    /// Collective-matching equivalence: same kind and same label. The
    /// payload details (send counts, partner) are *allowed* to differ
    /// across ranks — that is what the flow and symmetry lints check.
    fn same_shape(&self, other: &Event) -> bool {
        std::mem::discriminant(self) == std::mem::discriminant(other)
            && self.label() == other.label()
    }

    /// One-line rendering for the per-rank tables.
    fn describe(&self) -> String {
        match self {
            Event::Compute { label } => format!("C({label})"),
            Event::AllToAll { label, send_counts } => {
                let out: usize = send_counts.iter().sum::<usize>();
                format!("A2A({label} out={out})")
            }
            Event::Pairwise { label, partner, words } => {
                format!("PW({label} <->{partner} words={words})")
            }
            Event::ExchangeStart { label, send_counts } => {
                let out: usize = send_counts.iter().sum::<usize>();
                format!("A2A+({label} out={out})")
            }
            Event::ExchangeFinish { label } => format!("A2A-({label})"),
            Event::Barrier { label } => format!("B({label})"),
            Event::SessionBegin { arena } => format!("S+({arena})"),
            Event::SessionEnd { arena } => format!("S-({arena})"),
        }
    }
}

/// A per-rank event-sequence schedule: `ranks[s]` is the exact sequence
/// of supersteps rank `s` will execute, in order. Extracted from plan
/// metadata only — no payload exists when it is built.
#[derive(Clone, Debug)]
pub struct Schedule {
    /// Per-rank event sequences; mutable on purpose so the
    /// seeded-mutation tests can break a recorded schedule and prove the
    /// lints fire.
    pub ranks: Vec<Vec<Event>>,
}

impl Schedule {
    /// Record a schedule by running `body` once per rank with a
    /// [`RecordingCtx`] — the schedule analogue of
    /// [`crate::bsp::run_spmd`], except nothing executes: `body` only
    /// narrates the events the real SPMD program would emit.
    pub fn record(p: usize, mut body: impl FnMut(&mut RecordingCtx)) -> Schedule {
        let mut ranks = Vec::with_capacity(p);
        for rank in 0..p {
            let mut rec = RecordingCtx { rank, p, events: Vec::new() };
            body(&mut rec);
            ranks.push(rec.events);
        }
        Schedule { ranks }
    }

    /// Processor count the schedule was recorded for.
    pub fn nprocs(&self) -> usize {
        self.ranks.len()
    }
}

/// The recording counterpart of [`crate::bsp::Ctx`]: the same call
/// shape (`begin_comp`, `exchange`, `pairwise_exchange`, `barrier`) plus
/// arena-session markers, but calls append typed [`Event`]s instead of
/// moving data. Extraction code reads plan metadata (packet lengths,
/// compiled redistribution send matrices, partner maps) and narrates.
#[derive(Debug)]
pub struct RecordingCtx {
    rank: usize,
    p: usize,
    events: Vec<Event>,
}

impl RecordingCtx {
    pub fn rank(&self) -> usize {
        self.rank
    }

    pub fn nprocs(&self) -> usize {
        self.p
    }

    /// Record a computation superstep (mirrors `Ctx::begin_comp`).
    pub fn begin_comp(&mut self, label: &'static str) {
        self.events.push(Event::Compute { label });
    }

    /// Record a collective all-to-all with this rank's exact per-
    /// destination word counts (mirrors `Ctx::exchange_swap`).
    pub fn exchange(&mut self, label: &'static str, send_counts: Vec<usize>) {
        assert_eq!(
            send_counts.len(),
            self.p,
            "send_counts must have one entry per rank"
        );
        self.events.push(Event::AllToAll { label, send_counts });
    }

    /// Record the start half of a split-phase all-to-all (mirrors
    /// `Ctx::exchange_start`): the packets enter the mailbox here, the
    /// superstep is charged at the matching finish.
    pub fn exchange_start(&mut self, label: &'static str, send_counts: Vec<usize>) {
        assert_eq!(
            send_counts.len(),
            self.p,
            "send_counts must have one entry per rank"
        );
        self.events.push(Event::ExchangeStart { label, send_counts });
    }

    /// Record the finish half of a split-phase all-to-all (mirrors
    /// `Ctx::exchange_finish`).
    pub fn exchange_finish(&mut self, label: &'static str) {
        self.events.push(Event::ExchangeFinish { label });
    }

    /// Record a pairwise exchange (mirrors `Ctx::pairwise_exchange`).
    pub fn pairwise_exchange(&mut self, label: &'static str, partner: usize, words: usize) {
        self.events.push(Event::Pairwise { label, partner, words });
    }

    /// Record a bare barrier (mirrors `Ctx::barrier`).
    pub fn barrier(&mut self, label: &'static str) {
        self.events.push(Event::Barrier { label });
    }

    /// Record the driver leasing the named arena.
    pub fn session_begin(&mut self, arena: &'static str) {
        self.events.push(Event::SessionBegin { arena });
    }

    /// Record the driver releasing the named arena.
    pub fn session_end(&mut self, arena: &'static str) {
        self.events.push(Event::SessionEnd { arena });
    }
}

/// The six schedule lints, in the order [`verify`] runs them.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Lint {
    /// All ranks emit the same event-kind/label sequence, so no rank can
    /// stall on a mismatched collective or barrier (MPI collective
    /// matching).
    CollectiveMatching,
    /// Every pairwise superstep's partner map is an involution, and
    /// self-paired ranks synchronize only (send 0 words).
    PairwiseSymmetry,
    /// Per communication superstep: words sent == words received within
    /// each pair, the superstep structure matches the analytic ledger
    /// label-for-label, and the h-relation equals `analytic_h` exactly
    /// (Thm 2.1 becomes a machine-checked equality).
    FlowConservation,
    /// FFTU-family schedules contain exactly ONE collective all-to-all
    /// (Alg. 3.1); zig-zag conversion swaps and mirror swaps are
    /// pairwise, never collective. Baselines must match their documented
    /// collective count and use no pairwise steps.
    SingleAllToAll,
    /// No schedule re-enters a leased arena, leaves a lease open, or
    /// communicates outside a session (the `ExecArena` try-lock
    /// discipline, statically).
    SessionSafety,
    /// Every split-phase `exchange_start` is finished exactly once
    /// before its packet buffers can be reused: at most one exchange in
    /// flight per rank, every finish matches the in-flight start's
    /// label, no orphan finishes, nothing left in flight at schedule
    /// end, and no other communication superstep overlaps a flight
    /// window (the mailbox slots stay occupied until the finish drains
    /// them).
    SplitPhase,
}

impl Lint {
    pub fn name(self) -> &'static str {
        match self {
            Lint::CollectiveMatching => "collective-matching",
            Lint::PairwiseSymmetry => "pairwise-symmetry",
            Lint::FlowConservation => "flow-conservation",
            Lint::SingleAllToAll => "single-all-to-all",
            Lint::SessionSafety => "session-safety",
            Lint::SplitPhase => "split-phase",
        }
    }

    /// All lints, in [`verify`] order.
    pub fn all() -> [Lint; 6] {
        [
            Lint::CollectiveMatching,
            Lint::PairwiseSymmetry,
            Lint::FlowConservation,
            Lint::SingleAllToAll,
            Lint::SessionSafety,
            Lint::SplitPhase,
        ]
    }
}

/// One lint's verdict: passing means no recorded violations.
#[derive(Clone, Debug)]
pub struct LintOutcome {
    pub lint: Lint,
    pub violations: Vec<String>,
}

impl LintOutcome {
    pub fn passed(&self) -> bool {
        self.violations.is_empty()
    }
}

/// What the verifier may assume about the plan that produced a
/// schedule, derived from its [`crate::api::Algorithm`].
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct Expectations {
    /// FFTU family: exactly the plan's communication-superstep count of
    /// collectives, correctly labeled (see [`Self::ladder_stages`]);
    /// pairwise steps allowed (zig-zag conversions, mirror swaps).
    pub single_alltoall: bool,
    /// Expected collective count (the plan's `comm_stages` for FFTU —
    /// 1 up to sqrt(N); the documented `Algorithm::comm_supersteps`
    /// count for the baselines).
    pub collectives: usize,
    /// FFTU family only: communication supersteps per entry. `1` selects
    /// the classic single-all-to-all invariant (label `fftu-alltoall`);
    /// `k > 1` selects the beyond-sqrt(N) group-cyclic ladder — exactly
    /// `k` collectives per entry, labeled `fftu-ladder-0` through
    /// `fftu-ladder-{k-1}` **in stage order** (the shrinking-cycle
    /// sequence is positional, so a swapped, repeated, or dropped stage
    /// is a violation even when the count happens to survive). Ignored
    /// when `single_alltoall` is false.
    pub ladder_stages: usize,
    /// Modeled batch entries: 1 for the per-item schedules
    /// `PlannedFft::analyze` extracts, `b` for the pipelined batch
    /// schedules of `analyze_pipelined(b)`. The single-all-to-all
    /// invariant is *per entry*: a clean pipelined schedule carries
    /// exactly `b` collectives, every one labeled `fftu-alltoall`.
    pub batch: usize,
}

/// Run the full lint suite over a schedule. `analytic` is the matching
/// cost-model ledger ([`crate::costmodel`]) the flow lint compares
/// against. Pure function of its inputs, so the seeded-mutation tests
/// can mutate a recorded schedule and re-verify.
pub fn verify(
    schedule: &Schedule,
    analytic: &CostReport,
    exp: &Expectations,
) -> Vec<LintOutcome> {
    // Split-phase pairing is checked on the raw schedule; the five
    // positional lints then run on the normalized schedule, where every
    // start/finish pair has been fused into one `AllToAll` at the
    // finish position — the superstep the executed ledger charges.
    // Schedules without split-phase events normalize to themselves.
    let normalized = normalize_split_phase(schedule);
    vec![
        lint_collective_matching(&normalized),
        lint_pairwise_symmetry(&normalized),
        lint_flow_conservation(&normalized, analytic),
        lint_single_alltoall(&normalized, exp),
        lint_session_safety(&normalized),
        lint_split_phase(schedule),
    ]
}

/// Fuse every split-phase start/finish pair into a single
/// [`Event::AllToAll`] at the *finish* position (where the ledger
/// charges the communication superstep), carrying the start's send
/// counts. Orphan halves are dropped here — [`Lint::SplitPhase`]
/// convicts them on the raw schedule; dropping keeps the positional
/// lints from double-reporting the same defect.
fn normalize_split_phase(schedule: &Schedule) -> Schedule {
    let ranks = schedule
        .ranks
        .iter()
        .map(|events| {
            let mut out = Vec::with_capacity(events.len());
            let mut pending: Option<(&'static str, Vec<usize>)> = None;
            for e in events {
                match e {
                    Event::ExchangeStart { label, send_counts } => {
                        pending = Some((*label, send_counts.clone()));
                    }
                    Event::ExchangeFinish { label } => {
                        if let Some((started, send_counts)) = pending.take() {
                            if started == *label {
                                out.push(Event::AllToAll { label: started, send_counts });
                            }
                        }
                    }
                    other => out.push(other.clone()),
                }
            }
            out
        })
        .collect();
    Schedule { ranks }
}

/// Lint (a): every rank's event-kind/label sequence is identical.
fn lint_collective_matching(schedule: &Schedule) -> LintOutcome {
    let mut violations = Vec::new();
    let p = schedule.nprocs();
    if p > 0 {
        let reference = &schedule.ranks[0];
        for (rank, events) in schedule.ranks.iter().enumerate().skip(1) {
            if events.len() != reference.len() {
                violations.push(format!(
                    "rank {rank} emits {} events, rank 0 emits {} — a rank would stall \
                     on a missing superstep",
                    events.len(),
                    reference.len()
                ));
                continue;
            }
            for (i, (e, r)) in events.iter().zip(reference).enumerate() {
                if !e.same_shape(r) {
                    violations.push(format!(
                        "superstep {i}: rank {rank} emits {} '{}' where rank 0 emits {} '{}'",
                        e.kind_name(),
                        e.label(),
                        r.kind_name(),
                        r.label()
                    ));
                    break;
                }
            }
        }
        // Malformed collectives: a send-counts row must cover every rank.
        for (rank, events) in schedule.ranks.iter().enumerate() {
            for (i, e) in events.iter().enumerate() {
                if let Event::AllToAll { label, send_counts } = e {
                    if send_counts.len() != p {
                        violations.push(format!(
                            "superstep {i}: rank {rank}'s '{label}' send counts cover \
                             {} ranks, machine has {p}",
                            send_counts.len()
                        ));
                    }
                }
            }
        }
    }
    LintOutcome { lint: Lint::CollectiveMatching, violations }
}

/// The partner map of pairwise superstep position `i`, if every rank
/// has a pairwise event there with an in-range partner.
fn partner_map(schedule: &Schedule, i: usize) -> Option<Vec<usize>> {
    let p = schedule.nprocs();
    let mut partners = Vec::with_capacity(p);
    for events in &schedule.ranks {
        match events.get(i) {
            Some(Event::Pairwise { partner, .. }) if *partner < p => partners.push(*partner),
            _ => return None,
        }
    }
    Some(partners)
}

/// Lint (b): pairwise partner maps are involutions; self-pairs
/// synchronize only.
fn lint_pairwise_symmetry(schedule: &Schedule) -> LintOutcome {
    let mut violations = Vec::new();
    let p = schedule.nprocs();
    if p > 0 {
        for (i, e) in schedule.ranks[0].iter().enumerate() {
            if !matches!(e, Event::Pairwise { .. }) {
                continue;
            }
            // Per-rank partner validity first (partner_map needs it).
            let mut well_formed = true;
            for (rank, events) in schedule.ranks.iter().enumerate() {
                if let Some(Event::Pairwise { label, partner, words }) = events.get(i) {
                    if *partner >= p {
                        violations.push(format!(
                            "superstep {i} '{label}': rank {rank} pairs with rank \
                             {partner}, machine has {p}"
                        ));
                        well_formed = false;
                    } else if *partner == rank && *words != 0 {
                        violations.push(format!(
                            "superstep {i} '{label}': self-paired rank {rank} must \
                             synchronize only, sends {words} words"
                        ));
                    }
                } else {
                    // Shape mismatch — the collective lint reports it.
                    well_formed = false;
                }
            }
            if !well_formed {
                continue;
            }
            let partners =
                partner_map(schedule, i).expect("well-formed pairwise superstep has a map");
            for (s, &t) in partners.iter().enumerate() {
                if partners[t] != s {
                    violations.push(format!(
                        "superstep {i}: partner map is not an involution — rank {s} -> \
                         {t}, but rank {t} -> {} (rank {s} would block forever)",
                        partners[t]
                    ));
                }
            }
        }
    }
    LintOutcome { lint: Lint::PairwiseSymmetry, violations }
}

/// Lint (c): flow conservation against the analytic ledger.
///
/// The superstep structure (kind + label, barriers and session markers
/// aside) must match the analytic report one-for-one; each pair of a
/// pairwise exchange must send as many words as it receives; and every
/// communication superstep's h-relation must equal the analytic h
/// *exactly* — the static schedule carries the exact send matrix, so
/// Thm 2.1's bound is checked as an equality, not an inequality. Total
/// volume is also matched for pairwise supersteps, where the analytic
/// model records the exact sum (for the collectives it records the
/// `h * p` all-to-all convention, so only h is compared there).
fn lint_flow_conservation(schedule: &Schedule, analytic: &CostReport) -> LintOutcome {
    let mut violations = Vec::new();
    let p = schedule.nprocs();
    if p == 0 {
        return LintOutcome { lint: Lint::FlowConservation, violations };
    }
    // Structural match against the analytic ledger (rank 0's view; the
    // collective lint guarantees every rank agrees).
    let visible: Vec<&Event> = schedule.ranks[0]
        .iter()
        .filter(|e| !matches!(e, Event::SessionBegin { .. } | Event::SessionEnd { .. } | Event::Barrier { .. }))
        .collect();
    if visible.len() != analytic.supersteps.len() {
        violations.push(format!(
            "schedule has {} supersteps, analytic ledger has {}",
            visible.len(),
            analytic.supersteps.len()
        ));
    }
    for (j, (e, a)) in visible.iter().zip(&analytic.supersteps).enumerate() {
        let a_kind = match a.kind {
            SuperstepKind::Computation => "compute",
            SuperstepKind::Communication => "comm",
        };
        let matches_kind = match a.kind {
            SuperstepKind::Computation => matches!(e, Event::Compute { .. }),
            SuperstepKind::Communication => e.is_comm(),
        };
        if !matches_kind || e.label() != a.label {
            violations.push(format!(
                "superstep {j}: schedule has {} '{}', analytic ledger has {a_kind} '{}'",
                e.kind_name(),
                e.label(),
                a.label
            ));
        }
    }
    // Per-communication-superstep balance and h equality. Walk rank 0's
    // comm positions alongside the analytic comm supersteps.
    let comm_positions: Vec<usize> = schedule.ranks[0]
        .iter()
        .enumerate()
        .filter(|(_, e)| e.is_comm())
        .map(|(i, _)| i)
        .collect();
    let analytic_comms: Vec<_> = analytic
        .supersteps
        .iter()
        .filter(|s| s.kind == SuperstepKind::Communication)
        .collect();
    if comm_positions.len() != analytic_comms.len() {
        violations.push(format!(
            "schedule has {} communication supersteps, analytic ledger has {}",
            comm_positions.len(),
            analytic_comms.len()
        ));
        return LintOutcome { lint: Lint::FlowConservation, violations };
    }
    for (&i, a) in comm_positions.iter().zip(&analytic_comms) {
        let mut out = vec![0usize; p];
        let mut inn = vec![0usize; p];
        let mut well_formed = true;
        match &schedule.ranks[0][i] {
            Event::AllToAll { .. } => {
                // Gather the full send matrix; in[t] follows from out rows.
                for (s, events) in schedule.ranks.iter().enumerate() {
                    match events.get(i) {
                        Some(Event::AllToAll { send_counts, .. })
                            if send_counts.len() == p =>
                        {
                            for (t, &w) in send_counts.iter().enumerate() {
                                if t != s {
                                    out[s] += w;
                                    inn[t] += w;
                                }
                            }
                        }
                        _ => well_formed = false,
                    }
                }
            }
            Event::Pairwise { .. } => {
                let Some(partners) = partner_map(schedule, i) else {
                    // Malformed partners — symmetry lint reports.
                    continue;
                };
                let words: Vec<usize> = schedule
                    .ranks
                    .iter()
                    .map(|events| match &events[i] {
                        Event::Pairwise { words, .. } => *words,
                        _ => unreachable!("partner_map checked the event kind"),
                    })
                    .collect();
                for (s, &t) in partners.iter().enumerate() {
                    if t == s {
                        continue;
                    }
                    out[s] = words[s];
                    inn[s] = words[t];
                    if words[s] != words[t] {
                        violations.push(format!(
                            "superstep {i} '{}': rank {s} sends {} words but its \
                             partner {t} sends {} back — pair flow is unbalanced",
                            a.label, words[s], words[t]
                        ));
                    }
                }
                let total: usize = out.iter().sum();
                if total != a.words_total {
                    violations.push(format!(
                        "superstep {i} '{}': schedule moves {total} words total, \
                         analytic ledger says {}",
                        a.label, a.words_total
                    ));
                }
            }
            _ => unreachable!("comm_positions only holds comm events"),
        }
        if !well_formed {
            // Shape/count problems are the other lints' findings.
            continue;
        }
        let sent: usize = out.iter().sum();
        let received: usize = inn.iter().sum();
        if sent != received {
            violations.push(format!(
                "superstep {i} '{}': {sent} words sent != {received} words received",
                a.label
            ));
        }
        let h = out
            .iter()
            .zip(&inn)
            .map(|(&o, &r)| o.max(r))
            .max()
            .unwrap_or(0);
        if h != a.h_max {
            violations.push(format!(
                "superstep {i} '{}': schedule h-relation {h} != analytic h {}",
                a.label, a.h_max
            ));
        }
    }
    LintOutcome { lint: Lint::FlowConservation, violations }
}

/// Lint (d): the single-all-to-all invariant (FFTU) / the documented
/// collective count (baselines).
fn lint_single_alltoall(schedule: &Schedule, exp: &Expectations) -> LintOutcome {
    let mut violations = Vec::new();
    for (rank, events) in schedule.ranks.iter().enumerate() {
        let collectives: Vec<&Event> =
            events.iter().filter(|e| matches!(e, Event::AllToAll { .. })).collect();
        let pairwise = events.iter().filter(|e| matches!(e, Event::Pairwise { .. })).count();
        let per_entry = exp.batch.max(1);
        if exp.single_alltoall {
            let k = exp.ladder_stages.max(1);
            if collectives.len() != k * per_entry {
                violations.push(if k == 1 && per_entry == 1 {
                    format!(
                        "rank {rank}: FFTU path must contain exactly ONE all-to-all \
                         (Alg. 3.1), found {}",
                        collectives.len()
                    )
                } else if k == 1 {
                    format!(
                        "rank {rank}: pipelined FFTU batch must contain exactly ONE \
                         all-to-all per entry (Alg. 3.1) = {per_entry}, found {}",
                        collectives.len()
                    )
                } else {
                    format!(
                        "rank {rank}: beyond-sqrt(N) FFTU must contain exactly \
                         comm_supersteps_needed = {k} ladder exchanges per entry \
                         ({} total), found {}",
                        k * per_entry,
                        collectives.len()
                    )
                });
            }
            for (i, e) in collectives.iter().enumerate() {
                if k == 1 {
                    if e.label() != "fftu-alltoall" {
                        violations.push(format!(
                            "rank {rank}: collective '{}' is not the FFTU all-to-all — \
                             conversion/mirror swaps must be pairwise",
                            e.label()
                        ));
                    }
                } else {
                    // Stage order is part of the invariant: the cycle
                    // sequence c -> c/m only telescopes if the stages
                    // run 0, 1, ..., k-1 in every entry.
                    let stage = i % k;
                    let want = crate::fftu::LADDER_COMM_LABELS[stage];
                    if e.label() != want {
                        violations.push(format!(
                            "rank {rank}: collective {i} is '{}', expected ladder \
                             stage {stage} ('{want}') — stages must run in shrinking-\
                             cycle order",
                            e.label()
                        ));
                    }
                }
            }
        } else {
            if collectives.len() != exp.collectives * per_entry {
                violations.push(format!(
                    "rank {rank}: expected {} collective supersteps, found {}",
                    exp.collectives * per_entry,
                    collectives.len()
                ));
            }
            if pairwise != 0 {
                violations.push(format!(
                    "rank {rank}: {pairwise} pairwise supersteps in a baseline \
                     schedule (only the FFTU family uses pairwise exchanges)"
                ));
            }
        }
    }
    LintOutcome { lint: Lint::SingleAllToAll, violations }
}

/// Lint (e): arena session safety.
fn lint_session_safety(schedule: &Schedule) -> LintOutcome {
    let mut violations = Vec::new();
    for (rank, events) in schedule.ranks.iter().enumerate() {
        let mut open: Vec<&'static str> = Vec::new();
        for (i, e) in events.iter().enumerate() {
            match e {
                Event::SessionBegin { arena } => {
                    if open.contains(arena) {
                        violations.push(format!(
                            "rank {rank}, superstep {i}: schedule re-enters the leased \
                             arena '{arena}' — interleaved sessions cross-deadlock on \
                             the worker locks"
                        ));
                    } else {
                        open.push(arena);
                    }
                }
                Event::SessionEnd { arena } => match open.iter().rposition(|a| a == arena) {
                    Some(pos) => {
                        open.remove(pos);
                    }
                    None => violations.push(format!(
                        "rank {rank}, superstep {i}: releases arena '{arena}' without \
                         holding a lease"
                    )),
                },
                e if e.is_comm() => {
                    if open.is_empty() {
                        violations.push(format!(
                            "rank {rank}, superstep {i}: {} '{}' outside any arena \
                             session",
                            e.kind_name(),
                            e.label()
                        ));
                    }
                }
                _ => {}
            }
        }
        if let Some(arena) = open.first() {
            violations.push(format!(
                "rank {rank}: arena '{arena}' is still leased when the schedule ends"
            ));
        }
    }
    LintOutcome { lint: Lint::SessionSafety, violations }
}

/// Lint (f): split-phase exchange discipline, checked on the raw
/// schedule (before [`verify`] fuses start/finish pairs). The packet
/// buffers an `exchange_start` deposited stay leased to the mailbox
/// until the matching `exchange_finish` drains every slot, so reusing
/// them — a second start, or any blocking communication — before the
/// finish is a protocol violation even when no data race is observable
/// on a given run.
fn lint_split_phase(schedule: &Schedule) -> LintOutcome {
    let mut violations = Vec::new();
    for (rank, events) in schedule.ranks.iter().enumerate() {
        let mut pending: Option<(&'static str, usize)> = None;
        for (i, e) in events.iter().enumerate() {
            match e {
                Event::ExchangeStart { label, .. } => {
                    if let Some((in_flight, j)) = pending {
                        violations.push(format!(
                            "rank {rank}, superstep {i}: exchange_start '{label}' while \
                             '{in_flight}' (superstep {j}) is still in flight — the \
                             mailbox slots would be reused before the finish drains them"
                        ));
                    }
                    pending = Some((*label, i));
                }
                Event::ExchangeFinish { label } => match pending.take() {
                    None => violations.push(format!(
                        "rank {rank}, superstep {i}: exchange_finish '{label}' without \
                         a matching exchange_start"
                    )),
                    Some((in_flight, j)) if in_flight != *label => violations.push(format!(
                        "rank {rank}, superstep {i}: exchange_finish '{label}' does not \
                         match the in-flight start '{in_flight}' (superstep {j})"
                    )),
                    Some(_) => {}
                },
                Event::AllToAll { .. } | Event::Pairwise { .. } => {
                    if let Some((in_flight, j)) = pending {
                        violations.push(format!(
                            "rank {rank}, superstep {i}: {} '{}' overlaps the in-flight \
                             exchange '{in_flight}' (superstep {j}) — blocking \
                             communication would collide with the occupied mailbox slots",
                            e.kind_name(),
                            e.label()
                        ));
                    }
                }
                _ => {}
            }
        }
        if let Some((in_flight, j)) = pending {
            violations.push(format!(
                "rank {rank}: exchange_start '{in_flight}' (superstep {j}) is never \
                 finished — its packets are stranded in the mailbox"
            ));
        }
    }
    LintOutcome { lint: Lint::SplitPhase, violations }
}

/// The result of [`crate::api::PlannedFft::analyze`]: the extracted
/// schedule, the analytic ledger it was checked against, and every
/// lint's verdict.
#[derive(Clone, Debug)]
pub struct ScheduleReport {
    pub algorithm: &'static str,
    pub kind: &'static str,
    pub strategy: &'static str,
    pub shape: Vec<usize>,
    pub grid: Option<Vec<usize>>,
    pub procs: usize,
    pub expectations: Expectations,
    pub schedule: Schedule,
    pub analytic: CostReport,
    pub lints: Vec<LintOutcome>,
}

impl ScheduleReport {
    /// True when every lint passed.
    pub fn passed(&self) -> bool {
        self.lints.iter().all(LintOutcome::passed)
    }

    /// Re-run the lint suite over the (possibly mutated) schedule —
    /// what the seeded-mutation tests call after breaking an invariant.
    pub fn reverify(&mut self) {
        self.lints = verify(&self.schedule, &self.analytic, &self.expectations);
    }

    /// Human-readable rendering: the superstep table, per-rank schedule
    /// lines, and the lint verdicts.
    pub fn render(&self) -> String {
        let mut s = String::new();
        let dims = |v: &[usize]| {
            v.iter().map(|x| x.to_string()).collect::<Vec<_>>().join("x")
        };
        let _ = write!(
            s,
            "schedule: algorithm={} kind={} dist={} shape={} p={}",
            self.algorithm,
            self.kind,
            self.strategy,
            dims(&self.shape),
            self.procs
        );
        if let Some(grid) = &self.grid {
            let _ = write!(s, " grid={}", dims(grid));
        }
        s.push('\n');
        if let Some(reference) = self.schedule.ranks.first() {
            s.push_str("superstep structure (all ranks, by collective matching):\n");
            for (i, e) in reference.iter().enumerate() {
                let _ = write!(s, "  {i:>3}  {:<10} {}", e.kind_name(), e.label());
                if e.is_comm() {
                    let (h, total) = self.comm_stats(i);
                    let _ = write!(s, "  h={h} total={total}");
                }
                s.push('\n');
            }
            s.push_str("per-rank schedule:\n");
            for (rank, events) in self.schedule.ranks.iter().enumerate() {
                let line: Vec<String> = events.iter().map(Event::describe).collect();
                let _ = writeln!(s, "  rank {rank:>3}: {}", line.join(" "));
            }
        }
        s.push_str("lints:\n");
        for outcome in &self.lints {
            let verdict = if outcome.passed() { "ok" } else { "VIOLATION" };
            let _ = writeln!(s, "  {:<20} {verdict}", outcome.lint.name());
            for v in &outcome.violations {
                let _ = writeln!(s, "    - {v}");
            }
        }
        let _ = writeln!(s, "verdict: {}", if self.passed() { "PASS" } else { "FAIL" });
        s
    }

    /// (h, total words) of the communication superstep at event index
    /// `i`, computed from the schedule's exact send matrix.
    fn comm_stats(&self, i: usize) -> (usize, usize) {
        let p = self.schedule.nprocs();
        let mut out = vec![0usize; p];
        let mut inn = vec![0usize; p];
        for (s, events) in self.schedule.ranks.iter().enumerate() {
            match events.get(i) {
                Some(
                    Event::AllToAll { send_counts, .. }
                    | Event::ExchangeStart { send_counts, .. },
                ) => {
                    for (t, &w) in send_counts.iter().enumerate() {
                        if t != s && t < p {
                            out[s] += w;
                            inn[t] += w;
                        }
                    }
                }
                Some(Event::Pairwise { partner, words, .. }) => {
                    if *partner != s && *partner < p {
                        out[s] += words;
                        inn[*partner] += words;
                    }
                }
                _ => {}
            }
        }
        let h = out.iter().zip(&inn).map(|(&o, &r)| o.max(r)).max().unwrap_or(0);
        (h, out.iter().sum())
    }
}
