//! Plan -> schedule extraction: narrate, per rank, the exact superstep
//! events each executor will emit, reading only plan metadata (packet
//! lengths, compiled redistribution send matrices, partner maps). The
//! event orders below mirror the executor bodies in `fftu/mod.rs`,
//! `fftu/zigzag.rs`, and `baselines/*` one-for-one; the flow lint then
//! checks them against the analytic cost model, so a drift between an
//! executor and its extraction shows up as a lint violation in the
//! `analysis` test sweep.
//!
//! Everything here is `O(d · p)` per rank (the redistribution helpers
//! read precompiled placement lengths, never payload).

use crate::baselines::{HefftePlan, OutputDist, PencilPlan, PopoviciPlan, SlabPlan};
use crate::dist::RedistPlan;
use crate::fftu::{zigzag, FftuPlan};

use super::RecordingCtx;

/// Alg. 2.3 / 3.1 core: superstep 0 (local FFTs + twiddle), the single
/// all-to-all, superstep 2 (strided FFTs). The send count to *every*
/// rank — self included, matching the packet layout — is the plan's
/// packet length; the lints and the exchange both skip the self entry
/// when charging.
pub fn fftu_core(rec: &mut RecordingCtx, plan: &FftuPlan) {
    let p = plan.num_procs();
    rec.begin_comp("fftu-superstep0");
    rec.exchange("fftu-alltoall", vec![plan.packet_len(); p]);
    rec.begin_comp("fftu-superstep2");
}

/// Zig-zag <-> cyclic conversion (`convert_between_cyclic_and_zigzag`):
/// no events at all when no axis has `p_l >= 3`; otherwise one pairwise
/// exchange per such axis in increasing axis order, each moving half the
/// local array — or 0 words for a rank that is its own partner on that
/// axis (it still synchronizes).
pub fn zigzag_convert(rec: &mut RecordingCtx, plan: &FftuPlan) {
    if zigzag::exchange_axis_count(&plan.pgrid) == 0 {
        return;
    }
    let s_coords = plan.dist.proc_coords(rec.rank());
    let half = plan.local_len() / 2;
    for (axis, &q) in plan.pgrid.iter().enumerate() {
        if q < 3 {
            continue;
        }
        let partner = zigzag::axis_partner_rank(&plan.pgrid, &s_coords, axis);
        let words = if partner == rec.rank() { 0 } else { half };
        rec.pairwise_exchange("zigzag-exchange", partner, words);
    }
}

/// Conjugate mirror swap (`zigzag::mirror_swap`): the r2c path swaps the
/// whole local core output with the mirror rank; the c2r path also
/// carries the Nyquist/DC extra rows (`with_extra_rows`). Self-conjugate
/// ranks synchronize only.
pub fn mirror_swap(
    rec: &mut RecordingCtx,
    plan: &FftuPlan,
    label: &'static str,
    with_extra_rows: bool,
) {
    let s_coords = plan.dist.proc_coords(rec.rank());
    let partner = zigzag::mirror_partner_rank(&plan.pgrid, &s_coords);
    let mut payload = plan.local_len();
    if with_extra_rows {
        payload += zigzag::spectrum_extra_rows(plan, &s_coords);
    }
    let words = if partner == rec.rank() { 0 } else { payload };
    rec.pairwise_exchange(label, partner, words);
}

/// One compiled redistribution as a collective: this rank's exact
/// per-destination word counts come straight off the compiled placement
/// tables ([`RedistPlan::send_counts`]).
pub fn redist(rec: &mut RecordingCtx, label: &'static str, plan: &RedistPlan) {
    let counts = plan.send_counts(rec.rank());
    rec.exchange(label, counts);
}

/// Slab pipeline: local axes, the global transpose, axis 0, and (same-
/// distribution output only) the transpose back.
pub fn slab(rec: &mut RecordingCtx, plan: &SlabPlan) {
    rec.begin_comp("slab-local-axes");
    redist(rec, "slab-transpose", plan.transpose_plan());
    rec.begin_comp("slab-axis0");
    if plan.output_dist() == OutputDist::Same {
        redist(rec, "slab-transpose-back", plan.back_plan());
    }
}

/// PFFT-style r-dimensional decomposition: initial local axes, then one
/// (transpose, stage-axes) pair per redistribution stage, then the
/// optional transpose back.
pub fn pencil(rec: &mut RecordingCtx, plan: &PencilPlan) {
    rec.begin_comp("pencil-local-axes");
    for stage in plan.redist_plans() {
        redist(rec, "pencil-transpose", stage);
        rec.begin_comp("pencil-stage-axes");
    }
    if plan.output_dist() == OutputDist::Same {
        redist(rec, "pencil-transpose-back", plan.back_plan());
    }
}

/// heFFTe brick-to-brick pipeline: one (reshape, axis transform) pair
/// per stage, then the reshape back out to bricks.
pub fn heffte(rec: &mut RecordingCtx, plan: &HefftePlan) {
    let redists = plan.redist_plans();
    let stages = plan.stage_axes().len();
    for stage in &redists[..stages] {
        redist(rec, "heffte-reshape", stage);
        rec.begin_comp("heffte-axis");
    }
    redist(rec, "heffte-reshape-out", &redists[stages]);
}

/// Popovici-style cyclic d-step pipeline: per axis, a local-FFT
/// superstep, an all-to-all along that axis' grid row (packets only to
/// the `p_l` ranks sharing all other coordinates), and a strided-FFT
/// superstep.
pub fn popovici(rec: &mut RecordingCtx, plan: &PopoviciPlan) {
    let dist = plan.input_dist();
    let p = dist.num_procs();
    let coords = dist.proc_coords(rec.rank());
    for (l, &p_l) in plan.pgrid().iter().enumerate() {
        rec.begin_comp("popovici-local-fft");
        let mut counts = vec![0usize; p];
        let packet = plan.axis_packet_len(l);
        for k in 0..p_l {
            let mut tc = coords.clone();
            tc[l] = k;
            counts[dist.proc_rank(&tc)] = packet;
        }
        rec.exchange("popovici-alltoall", counts);
        rec.begin_comp("popovici-strided-fft");
    }
}
