//! Plan -> schedule extraction: narrate, per rank, the exact superstep
//! events each executor will emit, reading only plan metadata (packet
//! lengths, compiled redistribution send matrices, partner maps). The
//! event orders below mirror the executor bodies in `fftu/mod.rs`,
//! `fftu/zigzag.rs`, and `baselines/*` one-for-one; the flow lint then
//! checks them against the analytic cost model, so a drift between an
//! executor and its extraction shows up as a lint violation in the
//! `analysis` test sweep.
//!
//! Everything here is `O(d · p)` per rank (the redistribution helpers
//! read precompiled placement lengths, never payload).

use crate::baselines::{HefftePlan, OutputDist, PencilPlan, PopoviciPlan, SlabPlan};
use crate::bsp::CostReport;
use crate::dist::RedistPlan;
use crate::fftu::{zigzag, FftuPlan};

use super::{Event, RecordingCtx, Schedule};

/// Alg. 2.3 / 3.1 core: superstep 0 (local FFTs + twiddle), the single
/// all-to-all, superstep 2 (strided FFTs). The send count to *every*
/// rank — self included, matching the packet layout — is the plan's
/// packet length; the lints and the exchange both skip the self entry
/// when charging.
///
/// Beyond sqrt(N) the plan carries the group-cyclic ladder instead:
/// after the unchanged superstep 0, each of the `k` stages exchanges
/// `stage.words`-word packets within its team (the `prod_l m_l` ranks
/// sharing this rank's group-cyclic cosets — send counts are zero
/// outside the team) and then runs the per-axis `F_{m_l}` butterflies
/// plus the stage twiddle as one computation superstep. Mirrors
/// `Worker::execute_ladder` one-for-one.
pub fn fftu_core(rec: &mut RecordingCtx, plan: &FftuPlan) {
    let p = plan.num_procs();
    rec.begin_comp("fftu-superstep0");
    match &plan.ladder {
        None => {
            rec.exchange("fftu-alltoall", vec![plan.packet_len(); p]);
            rec.begin_comp("fftu-superstep2");
        }
        Some(lad) => {
            for (j, stage) in lad.stages.iter().enumerate() {
                let mut counts = vec![0usize; p];
                for &r in plan.ladder_team_ranks(rec.rank(), j).iter() {
                    counts[r as usize] = stage.words;
                }
                rec.exchange(stage.comm_label, counts);
                rec.begin_comp(stage.fft_label);
            }
        }
    }
}

/// Zig-zag <-> cyclic conversion (`convert_between_cyclic_and_zigzag`):
/// no events at all when no axis has `p_l >= 3`; otherwise one pairwise
/// exchange per such axis in increasing axis order, each moving half the
/// local array — or 0 words for a rank that is its own partner on that
/// axis (it still synchronizes).
pub fn zigzag_convert(rec: &mut RecordingCtx, plan: &FftuPlan) {
    if zigzag::exchange_axis_count(&plan.pgrid) == 0 {
        return;
    }
    let s_coords = plan.dist.proc_coords(rec.rank());
    let half = plan.local_len() / 2;
    for (axis, &q) in plan.pgrid.iter().enumerate() {
        if q < 3 {
            continue;
        }
        let partner = zigzag::axis_partner_rank(&plan.pgrid, &s_coords, axis);
        let words = if partner == rec.rank() { 0 } else { half };
        rec.pairwise_exchange("zigzag-exchange", partner, words);
    }
}

/// Conjugate mirror swap (`zigzag::mirror_swap`): the r2c path swaps the
/// whole local core output with the mirror rank; the c2r path also
/// carries the Nyquist/DC extra rows (`with_extra_rows`). Self-conjugate
/// ranks synchronize only.
pub fn mirror_swap(
    rec: &mut RecordingCtx,
    plan: &FftuPlan,
    label: &'static str,
    with_extra_rows: bool,
) {
    let s_coords = plan.dist.proc_coords(rec.rank());
    let partner = zigzag::mirror_partner_rank(&plan.pgrid, &s_coords);
    let mut payload = plan.local_len();
    if with_extra_rows {
        payload += zigzag::spectrum_extra_rows(plan, &s_coords);
    }
    let words = if partner == rec.rank() { 0 } else { payload };
    rec.pairwise_exchange(label, partner, words);
}

/// One compiled redistribution as a collective: this rank's exact
/// per-destination word counts come straight off the compiled placement
/// tables ([`RedistPlan::send_counts`]).
pub fn redist(rec: &mut RecordingCtx, label: &'static str, plan: &RedistPlan) {
    let counts = plan.send_counts(rec.rank());
    rec.exchange(label, counts);
}

/// Slab pipeline: local axes, the global transpose, axis 0, and (same-
/// distribution output only) the transpose back.
pub fn slab(rec: &mut RecordingCtx, plan: &SlabPlan) {
    rec.begin_comp("slab-local-axes");
    redist(rec, "slab-transpose", plan.transpose_plan());
    rec.begin_comp("slab-axis0");
    if plan.output_dist() == OutputDist::Same {
        redist(rec, "slab-transpose-back", plan.back_plan());
    }
}

/// PFFT-style r-dimensional decomposition: initial local axes, then one
/// (transpose, stage-axes) pair per redistribution stage, then the
/// optional transpose back.
pub fn pencil(rec: &mut RecordingCtx, plan: &PencilPlan) {
    rec.begin_comp("pencil-local-axes");
    for stage in plan.redist_plans() {
        redist(rec, "pencil-transpose", stage);
        rec.begin_comp("pencil-stage-axes");
    }
    if plan.output_dist() == OutputDist::Same {
        redist(rec, "pencil-transpose-back", plan.back_plan());
    }
}

/// heFFTe brick-to-brick pipeline: one (reshape, axis transform) pair
/// per stage, then the reshape back out to bricks.
pub fn heffte(rec: &mut RecordingCtx, plan: &HefftePlan) {
    let redists = plan.redist_plans();
    let stages = plan.stage_axes().len();
    for stage in &redists[..stages] {
        redist(rec, "heffte-reshape", stage);
        rec.begin_comp("heffte-axis");
    }
    redist(rec, "heffte-reshape-out", &redists[stages]);
}

/// Popovici-style cyclic d-step pipeline: per axis, a local-FFT
/// superstep, an all-to-all along that axis' grid row (packets only to
/// the `p_l` ranks sharing all other coordinates), and a strided-FFT
/// superstep.
pub fn popovici(rec: &mut RecordingCtx, plan: &PopoviciPlan) {
    let dist = plan.input_dist();
    let p = dist.num_procs();
    let coords = dist.proc_coords(rec.rank());
    for (l, &p_l) in plan.pgrid().iter().enumerate() {
        rec.begin_comp("popovici-local-fft");
        let mut counts = vec![0usize; p];
        let packet = plan.axis_packet_len(l);
        for k in 0..p_l {
            let mut tc = coords.clone();
            tc[l] = k;
            counts[dist.proc_rank(&tc)] = packet;
        }
        rec.exchange("popovici-alltoall", counts);
        rec.begin_comp("popovici-strided-fft");
    }
}

// ---------------------------------------------------------------------
// Pipelined batch schedules.
// ---------------------------------------------------------------------

/// True for the events that survive into an executed/analytic ledger
/// (everything but barriers and arena-session markers).
fn is_visible(e: &Event) -> bool {
    !matches!(
        e,
        Event::Barrier { .. } | Event::SessionBegin { .. } | Event::SessionEnd { .. }
    )
}

/// Clone a run of one-item events into the pipelined stream, recording
/// each event's one-item visible index (`base + offset`) in `order`.
fn emit(out: &mut Vec<Event>, order: &mut Vec<usize>, run: &[Event], base: usize) {
    for (k, e) in run.iter().enumerate() {
        out.push(e.clone());
        order.push(base + k);
    }
}

/// Build the depth-2 software-pipelined batch schedule from a recorded
/// single-item schedule, mirroring the batch drivers in `fftu/mod.rs`:
/// while entry `i`'s packets are in flight between `exchange_start` and
/// `exchange_finish`, entry `i + 1` runs the compute prefix the driver
/// overlaps with the flight window — the leading `flight_prefix`
/// in-session supersteps (superstep 0 for most kinds, only the trig
/// phase pass for DCT3/DST3 zig-zag, nothing for zig-zag c2r, whose
/// flight window only scatters the next spectrum). Everything between
/// that prefix and the entry's own `exchange_start` — pairwise
/// conversion/mirror swaps included — runs after the previous entry's
/// finish, exactly as the drivers sequence it: pairwise exchanges can
/// never overlap an in-flight all-to-all (the mailbox slots are
/// occupied).
///
/// Returns the pipelined schedule plus the *visible-superstep order*:
/// for each non-barrier, non-session event of the normalized pipelined
/// schedule (start/finish pairs fused at the finish), the index of the
/// corresponding superstep in the one-item visible sequence.
/// [`pipeline_analytic`] replays a per-item analytic ledger in that
/// order — the exact order the executed ledger charges under
/// pipelining, since the all-to-all is charged at the finish.
///
/// `None` when the schedule does not have the FFTU shape this
/// transform understands: exactly one arena session containing exactly
/// one collective all-to-all, nothing before the session, a
/// compute-only facade tail after it, and no communication inside the
/// flight prefix. (The batch drivers fall back to the sequential loop
/// for exactly the same shapes.)
pub fn pipeline(
    one: &Schedule,
    batch: usize,
    flight_prefix: usize,
) -> Option<(Schedule, Vec<usize>)> {
    if batch <= 1 {
        let visible = one
            .ranks
            .first()
            .map(|events| events.iter().filter(|e| is_visible(e)).count())
            .unwrap_or(0);
        return Some((one.clone(), (0..visible).collect()));
    }
    let mut ranks = Vec::with_capacity(one.nprocs());
    let mut order = Vec::new();
    for (rank, events) in one.ranks.iter().enumerate() {
        let (pipelined, rank_order) = pipeline_rank(events, batch, flight_prefix)?;
        if rank == 0 {
            order = rank_order;
        }
        ranks.push(pipelined);
    }
    Some((Schedule { ranks }, order))
}

/// One rank's share of [`pipeline`].
fn pipeline_rank(
    events: &[Event],
    batch: usize,
    flight_prefix: usize,
) -> Option<(Vec<Event>, Vec<usize>)> {
    let (first, rest) = events.split_first()?;
    let arena = match first {
        Event::SessionBegin { arena } => *arena,
        _ => return None,
    };
    let end = rest.iter().position(|e| matches!(e, Event::SessionEnd { .. }))?;
    let body = &rest[..end];
    let tail = &rest[end + 1..];
    if body.iter().any(|e| matches!(e, Event::Barrier { .. }))
        || tail.iter().any(|e| !matches!(e, Event::Compute { .. }))
    {
        return None;
    }
    let m = body.iter().position(|e| matches!(e, Event::AllToAll { .. }))?;
    if body[m + 1..].iter().any(|e| matches!(e, Event::AllToAll { .. })) {
        return None; // per-entry single all-to-all is a precondition
    }
    let (label, send_counts) = match &body[m] {
        Event::AllToAll { label, send_counts } => (*label, send_counts.clone()),
        _ => unreachable!("position matched an all-to-all"),
    };
    let pre = &body[..m];
    let post = &body[m + 1..];
    if flight_prefix > pre.len() {
        return None;
    }
    let (pre_a, pre_b) = pre.split_at(flight_prefix);
    if pre_a.iter().any(Event::is_comm) {
        return None; // the flight window must stay compute-only
    }

    // One-item visible indices: body events are 0..body.len() (sessions
    // are outside, barriers were rejected above), tail follows.
    let mut out = Vec::new();
    let mut order = Vec::new();
    out.push(Event::SessionBegin { arena });
    emit(&mut out, &mut order, pre_a, 0);
    emit(&mut out, &mut order, pre_b, flight_prefix);
    out.push(Event::ExchangeStart { label, send_counts: send_counts.clone() });
    for i in 0..batch {
        if i + 1 < batch {
            emit(&mut out, &mut order, pre_a, 0);
        }
        out.push(Event::ExchangeFinish { label });
        order.push(m); // the fused collective is charged at the finish
        emit(&mut out, &mut order, post, m + 1);
        if i + 1 < batch {
            emit(&mut out, &mut order, pre_b, flight_prefix);
            out.push(Event::ExchangeStart { label, send_counts: send_counts.clone() });
        }
    }
    out.push(Event::SessionEnd { arena });
    for _ in 0..batch {
        emit(&mut out, &mut order, tail, body.len());
    }
    Some((out, order))
}

/// Replay a per-item analytic ledger in pipelined-executed order (the
/// visible-superstep order [`pipeline`] returns): superstep `j` of the
/// result is a copy of `one.supersteps[order[j]]`. Per-entry costs are
/// untouched — pipelining reorders supersteps, it never changes what
/// any of them charges, which is why Thm 2.1's per-entry `h <= N/p`
/// carries over to pipelined batches unchanged.
pub fn pipeline_analytic(one: &CostReport, order: &[usize]) -> CostReport {
    let supersteps = order.iter().map(|&j| one.supersteps[j].clone()).collect();
    CostReport { supersteps }
}
