//! The crate-wide typed error.
//!
//! Every fallible operation on the public surface — descriptor
//! validation, distribution construction, redistribution planning, and
//! algorithm planning/execution — returns [`FftError`] instead of the
//! stringly-typed `Result<_, String>` the crate started with. The
//! variants are structured so callers can branch on *why* a transform
//! was rejected (wrong rank, divisibility violation, processor ceiling,
//! buffer length) rather than parsing a message.

use std::fmt;

/// Why a distributed-FFT operation was rejected.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum FftError {
    /// A shape and a processor grid (or cycle vector) have different
    /// numbers of axes.
    RankMismatch { shape: usize, grid: usize },
    /// A per-axis positivity/divisibility constraint failed; `requires`
    /// states the rule that was violated (e.g. `"p_l^2 | n_l"`).
    AxisConstraint { axis: usize, n: usize, p: usize, requires: &'static str },
    /// The processor count exceeds the algorithm's ceiling for this
    /// shape (§1.2/§2.3 of the paper).
    TooManyProcs { algo: &'static str, p: usize, pmax: usize },
    /// No valid processor grid exists for this (shape, p) pair.
    NoValidGrid { p: usize, pmax: usize },
    /// Two distributions handed to a redistribution are incompatible.
    DistMismatch { reason: &'static str },
    /// An input buffer does not match the descriptor's element count.
    InputLength { expected: usize, got: usize },
    /// An execute entry point was called on a plan of a different
    /// [`crate::api::Kind`] (e.g. `execute` on an r2c plan, whose real
    /// input goes through `execute_r2c`).
    KindMismatch { kind: &'static str, call: &'static str, expected: &'static str },
    /// The transform descriptor itself is malformed (empty shape, zero
    /// batch, bad decomposition rank, ...).
    BadDescriptor { reason: String },
    /// A valid request this build cannot serve (e.g. the XLA engine
    /// without the `xla-pjrt` feature).
    Unsupported { reason: String },
}

impl fmt::Display for FftError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            FftError::RankMismatch { shape, grid } => {
                write!(f, "shape rank {shape} != processor grid rank {grid}")
            }
            FftError::AxisConstraint { axis, n, p, requires } => {
                write!(f, "axis {axis} (n = {n}, p = {p}) violates `{requires}`")
            }
            FftError::TooManyProcs { algo, p, pmax } => {
                write!(f, "{algo} supports at most p_max = {pmax} processors, got p = {p}")
            }
            FftError::NoValidGrid { p, pmax } => {
                write!(f, "no valid processor grid for p = {p} (p_max = {pmax})")
            }
            FftError::DistMismatch { reason } => {
                write!(f, "incompatible distributions: {reason}")
            }
            FftError::InputLength { expected, got } => {
                write!(f, "input length {got} does not match descriptor ({expected} elements)")
            }
            FftError::KindMismatch { kind, call, expected } => {
                write!(f, "`{call}` serves {expected} transforms, but this plan's kind is {kind}")
            }
            FftError::BadDescriptor { reason } => write!(f, "bad transform descriptor: {reason}"),
            FftError::Unsupported { reason } => write!(f, "unsupported: {reason}"),
        }
    }
}

impl std::error::Error for FftError {}

/// Lets `?` lift an [`FftError`] into the `Result<_, String>` layers
/// (CLI, property-test closures) without boilerplate.
impl From<FftError> for String {
    fn from(e: FftError) -> String {
        e.to_string()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn display_is_informative() {
        let e = FftError::AxisConstraint { axis: 1, n: 8, p: 4, requires: "p_l^2 | n_l" };
        let s = e.to_string();
        assert!(s.contains("axis 1") && s.contains("p_l^2 | n_l"), "{s}");
        let e = FftError::TooManyProcs { algo: "slab", p: 64, pmax: 8 };
        assert!(e.to_string().contains("p_max = 8"), "{e}");
    }

    #[test]
    fn converts_to_string_for_question_mark() {
        fn inner() -> Result<(), String> {
            Err(FftError::NoValidGrid { p: 7, pmax: 4 })?;
            Ok(())
        }
        assert!(inner().unwrap_err().contains("p = 7"));
    }
}
