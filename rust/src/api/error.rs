//! The crate-wide typed error.
//!
//! Every fallible operation on the public surface — descriptor
//! validation, distribution construction, redistribution planning, and
//! algorithm planning/execution — returns [`FftError`] instead of the
//! stringly-typed `Result<_, String>` the crate started with. The
//! variants are structured so callers can branch on *why* a transform
//! was rejected (wrong rank, divisibility violation, processor ceiling,
//! buffer length) rather than parsing a message.

use std::fmt;

use crate::bsp::{BspFailure, FailureCause};

/// Why a distributed-FFT operation was rejected (or, for the
/// `RankFailure` / `Timeout` variants, why an execution died).
///
/// `#[non_exhaustive]`: downstream matches need a wildcard arm, so new
/// failure variants stop being semver breaks.
#[derive(Clone, Debug, PartialEq, Eq)]
#[non_exhaustive]
pub enum FftError {
    /// A shape and a processor grid (or cycle vector) have different
    /// numbers of axes.
    RankMismatch { shape: usize, grid: usize },
    /// A per-axis positivity/divisibility constraint failed; `requires`
    /// states the rule that was violated (e.g. `"p_l^2 | n_l"`).
    AxisConstraint { axis: usize, n: usize, p: usize, requires: &'static str },
    /// The processor count exceeds the algorithm's ceiling for this
    /// shape (§1.2/§2.3 of the paper).
    TooManyProcs { algo: &'static str, p: usize, pmax: usize },
    /// No valid processor grid exists for this (shape, p) pair.
    NoValidGrid { p: usize, pmax: usize },
    /// Two distributions handed to a redistribution are incompatible.
    DistMismatch { reason: &'static str },
    /// An input buffer does not match the descriptor's element count.
    InputLength { expected: usize, got: usize },
    /// An execute entry point was fed a buffer domain the plan's
    /// [`crate::api::Kind`] cannot take (e.g. a `BatchIo::Complex`
    /// buffer into an r2c plan, which wants `BatchIo::Real` input);
    /// `expected` lists the kinds that COULD take the buffer.
    KindMismatch { kind: &'static str, call: &'static str, expected: &'static str },
    /// The transform descriptor itself is malformed (empty shape, zero
    /// batch, bad decomposition rank, ...).
    BadDescriptor { reason: String },
    /// A valid request this build cannot serve (e.g. the XLA engine
    /// without the `xla-pjrt` feature).
    Unsupported { reason: String },
    /// A BSP session died: one or more ranks panicked or detected a
    /// protocol violation. `rank` and `superstep` locate the
    /// first-detected failure; `detail` renders every recorded one.
    RankFailure { rank: usize, superstep: &'static str, detail: String },
    /// A BSP session exceeded its superstep deadline (a rank stalled or
    /// deadlocked); `superstep` is where the waiting rank gave up.
    Timeout { superstep: &'static str, detail: String },
}

impl fmt::Display for FftError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            FftError::RankMismatch { shape, grid } => {
                write!(f, "shape rank {shape} != processor grid rank {grid}")
            }
            FftError::AxisConstraint { axis, n, p, requires } => {
                write!(f, "axis {axis} (n = {n}, p = {p}) violates `{requires}`")
            }
            FftError::TooManyProcs { algo, p, pmax } => {
                write!(f, "{algo} supports at most p_max = {pmax} processors, got p = {p}")
            }
            FftError::NoValidGrid { p, pmax } => {
                write!(f, "no valid processor grid for p = {p} (p_max = {pmax})")
            }
            FftError::DistMismatch { reason } => {
                write!(f, "incompatible distributions: {reason}")
            }
            FftError::InputLength { expected, got } => {
                write!(f, "input length {got} does not match descriptor ({expected} elements)")
            }
            FftError::KindMismatch { kind, call, expected } => {
                write!(f, "`{call}` serves {expected} transforms, but this plan's kind is {kind}")
            }
            FftError::BadDescriptor { reason } => write!(f, "bad transform descriptor: {reason}"),
            FftError::Unsupported { reason } => write!(f, "unsupported: {reason}"),
            FftError::RankFailure { rank, superstep, detail } => {
                write!(f, "BSP session failed (first at rank {rank}, superstep '{superstep}'): {detail}")
            }
            FftError::Timeout { superstep, detail } => {
                write!(f, "BSP session timed out at superstep '{superstep}': {detail}")
            }
        }
    }
}

impl std::error::Error for FftError {}

/// Typed lift of a BSP session failure into the API error: a deadline
/// timeout anywhere in the registry becomes [`FftError::Timeout`],
/// anything else [`FftError::RankFailure`]; `detail` preserves every
/// recorded rank/superstep/cause.
impl From<BspFailure> for FftError {
    fn from(failure: BspFailure) -> FftError {
        let first = first_of(&failure);
        let detail = failure.to_string();
        if failure.timed_out() {
            FftError::Timeout { superstep: first.1, detail }
        } else {
            FftError::RankFailure { rank: first.0, superstep: first.1, detail }
        }
    }
}

fn first_of(failure: &BspFailure) -> (usize, &'static str) {
    // Prefer the first timeout record when one exists (it names the
    // superstep that actually stalled); otherwise the first failure.
    let f = failure
        .failures
        .iter()
        .find(|f| f.cause == FailureCause::Timeout)
        .unwrap_or_else(|| failure.first());
    (f.rank, f.superstep)
}

/// Lets `?` lift an [`FftError`] into the `Result<_, String>` layers
/// (CLI, property-test closures) without boilerplate.
impl From<FftError> for String {
    fn from(e: FftError) -> String {
        e.to_string()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn display_is_informative() {
        let e = FftError::AxisConstraint { axis: 1, n: 8, p: 4, requires: "p_l^2 | n_l" };
        let s = e.to_string();
        assert!(s.contains("axis 1") && s.contains("p_l^2 | n_l"), "{s}");
        let e = FftError::TooManyProcs { algo: "slab", p: 64, pmax: 8 };
        assert!(e.to_string().contains("p_max = 8"), "{e}");
    }

    #[test]
    fn bsp_failure_lifts_to_typed_variants() {
        use crate::bsp::RankFailure;
        let panic = BspFailure {
            failures: vec![RankFailure {
                rank: 2,
                superstep: "fftu-alltoall",
                cause: FailureCause::Panic("boom".into()),
            }],
        };
        let e = FftError::from(panic);
        assert!(
            matches!(e, FftError::RankFailure { rank: 2, superstep: "fftu-alltoall", .. }),
            "{e}"
        );
        let stall = BspFailure {
            failures: vec![
                RankFailure {
                    rank: 0,
                    superstep: "slab-transpose",
                    cause: FailureCause::Timeout,
                },
            ],
        };
        let e = FftError::from(stall);
        assert!(matches!(e, FftError::Timeout { superstep: "slab-transpose", .. }), "{e}");
    }

    #[test]
    fn converts_to_string_for_question_mark() {
        fn inner() -> Result<(), String> {
            Err(FftError::NoValidGrid { p: 7, pmax: 4 })?;
            Ok(())
        }
        assert!(inner().unwrap_err().contains("p = 7"));
    }
}
