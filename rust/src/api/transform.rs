//! The [`Transform`] descriptor: everything that identifies a
//! distributed multidimensional FFT *before* an algorithm is chosen —
//! shape, processor grid (explicit or auto-chosen), direction,
//! normalization, and batch count.
//!
//! The descriptor is plain data (`Eq + Hash`), which is what lets
//! [`super::PlanCache`] key plans by it.

use std::sync::Arc;

use crate::fft::realnd;
use crate::fft::Direction;

use super::error::FftError;
use super::plan::{plan, Algorithm, PlannedFft};

/// What the transform's input and output are made of.
///
/// - [`Kind::C2C`]: complex in, complex out — the default.
/// - [`Kind::R2C`]: real in, Hermitian half-spectrum out (shape
///   `[..., n_d/2 + 1]`, numpy `rfftn` layout). Forward-only; requires
///   an even last axis. Executed via the packing trick: the complex core
///   runs on the *half shape* `[..., n_d/2]`, so flops and communication
///   volume roughly halve (FFTU keeps its single all-to-all).
/// - [`Kind::C2R`]: Hermitian half-spectrum in, real out — the adjoint
///   of R2C. Inverse-only; with [`Normalization::ByN`] it is the exact
///   inverse of an unnormalized R2C.
///
/// Real-kind plans execute through the unified
/// [`super::PlannedFft::execute`] front door with a
/// [`super::BatchIo::Real`] input (R2C) or [`super::BatchIo::Complex`]
/// half-spectrum (C2R); feeding the wrong domain returns
/// [`FftError::KindMismatch`].
///
/// The four trig kinds are the paper's §6 DCT/DST extensions, scipy
/// conventions (types 2 and 3, `norm=None`):
///
/// - [`Kind::Dct2`] / [`Kind::Dst2`]: real in, real out, computed as a
///   per-axis Makhoul even-odd permutation (local; for FFTU folded into
///   the cyclic scatter) around a *forward* complex core on the full
///   shape, plus per-axis quarter-wave combine passes. Forward-only.
/// - [`Kind::Dct3`] / [`Kind::Dst3`]: the unnormalized inverses
///   (`type3(type2(x)) = prod_l (2 n_l) x`) — per-axis phase passes, an
///   *inverse* complex core, and the inverse permutation (folded into
///   FFTU's gather). Inverse-only.
///
/// Trig plans execute through the same front door with a
/// [`super::BatchIo::Real`] input; FFTU keeps exactly ONE all-to-all
/// for all four.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum Kind {
    C2C,
    R2C,
    C2R,
    Dct2,
    Dct3,
    Dst2,
    Dst3,
}

impl Kind {
    pub fn name(self) -> &'static str {
        match self {
            Kind::C2C => "c2c",
            Kind::R2C => "r2c",
            Kind::C2R => "c2r",
            Kind::Dct2 => "dct2",
            Kind::Dct3 => "dct3",
            Kind::Dst2 => "dst2",
            Kind::Dst3 => "dst3",
        }
    }

    /// Parse a CLI-style name.
    pub fn parse(s: &str) -> Option<Kind> {
        match s {
            "c2c" => Some(Kind::C2C),
            "r2c" => Some(Kind::R2C),
            "c2r" => Some(Kind::C2R),
            "dct2" => Some(Kind::Dct2),
            "dct3" => Some(Kind::Dct3),
            "dst2" => Some(Kind::Dst2),
            "dst3" => Some(Kind::Dst3),
            _ => None,
        }
    }

    /// The half-spectrum real-FFT kinds (packing trick): R2C and C2R.
    pub fn is_real_fft(self) -> bool {
        matches!(self, Kind::R2C | Kind::C2R)
    }

    /// The four trig kinds (DCT-II/III, DST-II/III).
    pub fn is_trig(self) -> bool {
        matches!(self, Kind::Dct2 | Kind::Dct3 | Kind::Dst2 | Kind::Dst3)
    }

    /// Direction of the complex core a non-C2C kind runs through (also
    /// the only valid descriptor direction for that kind): forward for
    /// R2C and the type-2 trig kinds, inverse for C2R and type 3.
    pub(crate) fn required_direction(self) -> Option<Direction> {
        match self {
            Kind::C2C => None,
            Kind::R2C | Kind::Dct2 | Kind::Dst2 => Some(Direction::Forward),
            Kind::C2R | Kind::Dct3 | Kind::Dst3 => Some(Direction::Inverse),
        }
    }
}

/// How a non-C2C transform's combine/untangle passes are distributed.
///
/// The complex core is identical either way (FFTU: ONE all-to-all);
/// the strategies differ in where the wrapper passes run:
///
/// - [`DistStrategy::Gathered`] (default): the quarter-wave combine
///   (trig kinds) or conjugate-symmetry untangle (r2c/c2r) runs at
///   facade level over the gathered array — the PR 2/PR 4 paths,
///   retained as the bit-exact differential oracles.
/// - [`DistStrategy::ZigZag`]: the passes run **rank-local**. The trig
///   kinds convert the core's cyclic data to the zig-zag cyclic
///   distribution ([`crate::dist::AxisDist::ZigZagCyclic`]) with one
///   pairwise exchange per axis (`p_l >= 3`), which co-locates every
///   mirror pair; r2c/c2r swap one copy with the conjugate partner
///   `-s mod p`. FFTU-only (the baselines keep the facade passes), and
///   the trig kinds additionally require `2 p_l | n_l` per shared axis.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum DistStrategy {
    Gathered,
    ZigZag,
}

impl DistStrategy {
    pub fn name(self) -> &'static str {
        match self {
            DistStrategy::Gathered => "gathered",
            DistStrategy::ZigZag => "zigzag",
        }
    }

    /// Parse a CLI-style name (`--dist gathered|zigzag`).
    pub fn parse(s: &str) -> Option<DistStrategy> {
        match s {
            "gathered" => Some(DistStrategy::Gathered),
            "zigzag" => Some(DistStrategy::ZigZag),
            _ => None,
        }
    }
}

/// Output scaling, applied uniformly for every algorithm and direction.
///
/// The raw transforms (like FFTW's) are unnormalized: a forward followed
/// by an inverse multiplies the data by `N`. The descriptor makes the
/// convention explicit instead of leaving callers to hand-divide:
///
/// - [`Normalization::None`]: no scaling (FFTW default);
/// - [`Normalization::Unitary`]: `1/sqrt(N)` — forward and inverse both
///   unitary, so any forward/inverse pair round-trips;
/// - [`Normalization::ByN`]: `1/N` — the classic inverse-transform
///   scaling; `Forward` with `None` then `Inverse` with `ByN` is the
///   identity.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum Normalization {
    None,
    Unitary,
    ByN,
}

impl Normalization {
    /// The scale factor for an `n`-element transform.
    pub fn scale(self, n: usize) -> f64 {
        match self {
            Normalization::None => 1.0,
            Normalization::Unitary => 1.0 / (n as f64).sqrt(),
            Normalization::ByN => 1.0 / n as f64,
        }
    }
}

/// Processor-grid request: either an explicit per-axis grid or a total
/// processor count resolved per algorithm (via
/// [`crate::fftu::choose_grid`] for the cyclic algorithms).
#[derive(Clone, Debug, PartialEq, Eq, Hash)]
pub enum Grid {
    /// `p` total processors; the planner picks the per-axis split.
    Auto { p: usize },
    /// Explicit per-axis processor counts (cyclic-family algorithms) —
    /// its product is the processor count for the slab/pencil/brick
    /// algorithms, which place processors themselves.
    Explicit(Vec<usize>),
}

impl Grid {
    /// Total processor count this request asks for.
    pub fn procs(&self) -> usize {
        match self {
            Grid::Auto { p } => *p,
            Grid::Explicit(g) => g.iter().product(),
        }
    }
}

/// Descriptor of one (possibly batched) distributed FFT.
///
/// Built with the fluent constructors and handed to
/// [`Transform::plan`] / [`super::plan`] / [`super::PlanCache::plan`]:
///
/// ```
/// use fftu::api::{Algorithm, Normalization, Transform};
/// let t = Transform::new(&[16, 16])
///     .procs(4)
///     .inverse()
///     .normalization(Normalization::ByN)
///     .batch(2);
/// assert_eq!(t.total(), 256);
/// assert!(t.plan(Algorithm::Fftu).is_ok());
/// ```
#[derive(Clone, Debug, PartialEq, Eq, Hash)]
pub struct Transform {
    /// Global array shape `n_1 x ... x n_d`.
    pub shape: Vec<usize>,
    /// Processor grid request.
    pub grid: Grid,
    /// Transform direction (`Forward` is `e^{-2 pi i jk/n}`).
    pub direction: Direction,
    /// Output scaling.
    pub normalization: Normalization,
    /// Number of independent transforms per [`super::DistFft::execute`]
    /// call; the input buffer holds `batch` arrays back to back.
    pub batch: usize,
    /// Input/output domain: complex-to-complex (default), real-to-complex,
    /// or complex-to-real. For the real kinds, `shape` is the *real*
    /// array shape and the grid applies to the packed half shape
    /// `[..., n_d/2]` the complex core runs on.
    pub kind: Kind,
    /// Where the non-C2C wrapper passes run: facade-level over the
    /// gathered array (default) or rank-local via the zig-zag cyclic
    /// distribution / conjugate pairwise exchange (FFTU only).
    pub strategy: DistStrategy,
}

impl Transform {
    /// A forward, unnormalized, single complex transform on one processor.
    pub fn new(shape: &[usize]) -> Self {
        Transform {
            shape: shape.to_vec(),
            grid: Grid::Auto { p: 1 },
            direction: Direction::Forward,
            normalization: Normalization::None,
            batch: 1,
            kind: Kind::C2C,
            strategy: DistStrategy::Gathered,
        }
    }

    /// Use an explicit per-axis processor grid.
    pub fn grid(mut self, grid: &[usize]) -> Self {
        self.grid = Grid::Explicit(grid.to_vec());
        self
    }

    /// Use `p` total processors, letting the planner pick the split.
    pub fn procs(mut self, p: usize) -> Self {
        self.grid = Grid::Auto { p };
        self
    }

    pub fn direction(mut self, dir: Direction) -> Self {
        self.direction = dir;
        self
    }

    pub fn forward(self) -> Self {
        self.direction(Direction::Forward)
    }

    pub fn inverse(self) -> Self {
        self.direction(Direction::Inverse)
    }

    pub fn normalization(mut self, norm: Normalization) -> Self {
        self.normalization = norm;
        self
    }

    pub fn batch(mut self, batch: usize) -> Self {
        self.batch = batch;
        self
    }

    /// Set the transform [`Kind`]. The non-C2C kinds fix the direction
    /// (R2C/DCT-II/DST-II are forward-only, C2R/DCT-III/DST-III
    /// inverse-only), overriding any earlier
    /// `direction`/`forward`/`inverse` call.
    pub fn kind(mut self, kind: Kind) -> Self {
        self.kind = kind;
        if let Some(dir) = kind.required_direction() {
            self.direction = dir;
        }
        self
    }

    /// Shorthand for [`Transform::kind`]`(Kind::R2C)`.
    pub fn r2c(self) -> Self {
        self.kind(Kind::R2C)
    }

    /// Shorthand for [`Transform::kind`]`(Kind::C2R)`.
    pub fn c2r(self) -> Self {
        self.kind(Kind::C2R)
    }

    /// Shorthand for [`Transform::kind`]`(Kind::Dct2)`.
    pub fn dct2(self) -> Self {
        self.kind(Kind::Dct2)
    }

    /// Shorthand for [`Transform::kind`]`(Kind::Dct3)`.
    pub fn dct3(self) -> Self {
        self.kind(Kind::Dct3)
    }

    /// Shorthand for [`Transform::kind`]`(Kind::Dst2)`.
    pub fn dst2(self) -> Self {
        self.kind(Kind::Dst2)
    }

    /// Shorthand for [`Transform::kind`]`(Kind::Dst3)`.
    pub fn dst3(self) -> Self {
        self.kind(Kind::Dst3)
    }

    /// Set the [`DistStrategy`] of the non-C2C wrapper passes.
    pub fn strategy(mut self, strategy: DistStrategy) -> Self {
        self.strategy = strategy;
        self
    }

    /// Shorthand for [`Transform::strategy`]`(DistStrategy::ZigZag)`:
    /// rank-local combine/untangle passes (FFTU only).
    pub fn zigzag(self) -> Self {
        self.strategy(DistStrategy::ZigZag)
    }

    /// Elements per transform in the *real* domain: the product of
    /// `shape`. For C2C this is also the complex element count.
    pub fn total(&self) -> usize {
        self.shape.iter().product()
    }

    /// Shape of the spectral-domain buffer: the Hermitian half-spectrum
    /// `[..., n_d/2 + 1]` for R2C/C2R, and `shape` itself for C2C and
    /// the trig kinds (whose coefficient arrays are real and full-size).
    pub fn spectrum_shape(&self) -> Vec<usize> {
        match self.kind {
            Kind::R2C | Kind::C2R => realnd::spectrum_shape(&self.shape),
            _ => self.shape.clone(),
        }
    }

    /// Complex elements per transform in the spectral domain.
    pub fn spectrum_total(&self) -> usize {
        self.spectrum_shape().iter().product()
    }

    /// The C2C descriptor of the complex core a non-C2C transform runs
    /// through: the packed half shape `[..., n_d/2]` for R2C/C2R, the
    /// full shape for the trig kinds (Makhoul permutes, it does not
    /// pack); same grid request and batch, unnormalized (the wrapper
    /// applies the descriptor's normalization once, against the real
    /// total `N`).
    pub(crate) fn complex_core(&self) -> Transform {
        debug_assert!(self.kind != Kind::C2C);
        let shape = if self.kind.is_trig() {
            self.shape.clone()
        } else {
            realnd::half_shape(&self.shape)
        };
        Transform {
            shape,
            grid: self.grid.clone(),
            direction: self.direction,
            normalization: Normalization::None,
            batch: self.batch,
            kind: Kind::C2C,
            // The strategy shapes the wrapper passes, not the core.
            strategy: DistStrategy::Gathered,
        }
    }

    /// Structural validation shared by every algorithm (the per-axis
    /// divisibility rules are the algorithms' own, checked at plan time).
    pub fn validate(&self) -> Result<(), FftError> {
        if self.shape.is_empty() {
            return Err(FftError::BadDescriptor { reason: "shape must have at least one axis".into() });
        }
        if let Some(axis) = self.shape.iter().position(|&n| n == 0) {
            return Err(FftError::AxisConstraint { axis, n: 0, p: 0, requires: "n_l >= 1" });
        }
        if self.batch == 0 {
            return Err(FftError::BadDescriptor { reason: "batch must be >= 1".into() });
        }
        if self.kind.is_real_fft() {
            realnd::validate_even_last_axis(&self.shape)?;
        }
        if self.strategy == DistStrategy::ZigZag && self.kind == Kind::C2C {
            return Err(FftError::BadDescriptor {
                reason: "the zig-zag strategy distributes the real/trig wrapper passes; \
                         c2c has none — use a non-c2c kind or the gathered strategy"
                    .into(),
            });
        }
        if let Some(required) = self.kind.required_direction() {
            if self.direction != required {
                return Err(FftError::BadDescriptor {
                    reason: format!(
                        "{} transforms are {:?}-only (got {:?}); the type-3/c2r kinds are \
                         the inverse paths",
                        self.kind.name(),
                        required,
                        self.direction
                    ),
                });
            }
        }
        match &self.grid {
            Grid::Auto { p: 0 } => {
                Err(FftError::BadDescriptor { reason: "processor count must be >= 1".into() })
            }
            Grid::Explicit(g) if g.len() != self.shape.len() => {
                Err(FftError::RankMismatch { shape: self.shape.len(), grid: g.len() })
            }
            Grid::Explicit(g) => match g.iter().position(|&p| p == 0) {
                Some(axis) => Err(FftError::AxisConstraint {
                    axis,
                    n: self.shape[axis],
                    p: 0,
                    requires: "p_l >= 1",
                }),
                None => Ok(()),
            },
            _ => Ok(()),
        }
    }

    /// Plan this descriptor with `algo` — shorthand for
    /// [`super::plan`]`(algo, self)`.
    pub fn plan(&self, algo: Algorithm) -> Result<Arc<PlannedFft>, FftError> {
        plan(algo, self)
    }

    /// Plan this descriptor with the autotuning planner — shorthand for
    /// [`Self::plan`]`(`[`Algorithm::Auto`]`)`. Every feasible
    /// (algorithm, grid, strategy) candidate is priced against the
    /// default [`crate::costmodel::Machine`] and the cheapest is
    /// planned; the decision is exposed through
    /// [`PlannedFft::chosen`]. Use [`super::planner::plan_auto`] to
    /// override the machine or request measured (trial-execute)
    /// planning.
    pub fn auto(&self) -> Result<Arc<PlannedFft>, FftError> {
        plan(Algorithm::Auto, self)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn builder_defaults_and_chaining() {
        let t = Transform::new(&[8, 4]);
        assert_eq!(t.grid, Grid::Auto { p: 1 });
        assert_eq!(t.direction, Direction::Forward);
        assert_eq!(t.normalization, Normalization::None);
        assert_eq!(t.batch, 1);
        let t = t.grid(&[2, 2]).inverse().normalization(Normalization::ByN).batch(3);
        assert_eq!(t.grid.procs(), 4);
        assert_eq!(t.direction, Direction::Inverse);
        assert_eq!(t.batch, 3);
        assert!(t.validate().is_ok());
    }

    #[test]
    fn validate_rejects_malformed_descriptors() {
        assert!(Transform::new(&[]).validate().is_err());
        assert!(Transform::new(&[8, 0]).validate().is_err());
        assert!(Transform::new(&[8]).batch(0).validate().is_err());
        assert!(Transform::new(&[8]).procs(0).validate().is_err());
        assert!(matches!(
            Transform::new(&[8, 8]).grid(&[2]).validate(),
            Err(FftError::RankMismatch { shape: 2, grid: 1 })
        ));
        assert!(Transform::new(&[8, 8]).grid(&[2, 0]).validate().is_err());
    }

    #[test]
    fn real_kinds_fix_direction_and_shapes() {
        let t = Transform::new(&[8, 12]).r2c();
        assert_eq!(t.kind, Kind::R2C);
        assert_eq!(t.direction, Direction::Forward);
        assert_eq!(t.spectrum_shape(), vec![8, 7]);
        assert_eq!(t.spectrum_total(), 56);
        assert!(t.validate().is_ok());
        let core = t.complex_core();
        assert_eq!(core.shape, vec![8, 6]);
        assert_eq!(core.kind, Kind::C2C);
        assert_eq!(core.normalization, Normalization::None);

        let t = Transform::new(&[8, 12]).c2r();
        assert_eq!(t.direction, Direction::Inverse);
        assert!(t.validate().is_ok());
        // kind() overrides an earlier direction call, but a later
        // explicit direction that contradicts the kind is rejected.
        assert!(Transform::new(&[8, 12]).inverse().r2c().validate().is_ok());
        assert!(Transform::new(&[8, 12]).r2c().inverse().validate().is_err());
        assert!(Transform::new(&[8, 12]).c2r().forward().validate().is_err());
        // Odd last axis cannot pack.
        assert!(matches!(
            Transform::new(&[8, 9]).r2c().validate(),
            Err(FftError::AxisConstraint { axis: 1, n: 9, .. })
        ));
        // C2C is unaffected.
        assert_eq!(Transform::new(&[8, 9]).spectrum_shape(), vec![8, 9]);
        assert!(Transform::new(&[8, 9]).validate().is_ok());
    }

    #[test]
    fn kind_names_round_trip() {
        for kind in [
            Kind::C2C,
            Kind::R2C,
            Kind::C2R,
            Kind::Dct2,
            Kind::Dct3,
            Kind::Dst2,
            Kind::Dst3,
        ] {
            assert_eq!(Kind::parse(kind.name()), Some(kind));
        }
        assert_eq!(Kind::parse("dct"), None);
    }

    #[test]
    fn trig_kinds_fix_direction_and_run_on_the_full_shape() {
        let t = Transform::new(&[8, 9]).dct2(); // odd axes are fine: no packing
        assert_eq!(t.kind, Kind::Dct2);
        assert_eq!(t.direction, Direction::Forward);
        assert_eq!(t.spectrum_shape(), vec![8, 9]);
        assert!(t.validate().is_ok());
        let core = t.complex_core();
        assert_eq!(core.shape, vec![8, 9]);
        assert_eq!(core.kind, Kind::C2C);
        assert_eq!(core.direction, Direction::Forward);

        let t = Transform::new(&[8, 9]).dst3();
        assert_eq!(t.direction, Direction::Inverse);
        assert!(t.validate().is_ok());
        assert_eq!(t.complex_core().direction, Direction::Inverse);

        // kind() overrides an earlier direction; a later contradictory
        // direction is rejected, exactly as for the real-FFT kinds.
        assert!(Transform::new(&[8]).inverse().dct2().validate().is_ok());
        assert!(Transform::new(&[8]).dct2().inverse().validate().is_err());
        assert!(Transform::new(&[8]).dct3().forward().validate().is_err());
        assert!(Transform::new(&[8]).dst2().inverse().validate().is_err());

        assert!(Kind::Dct2.is_trig() && !Kind::Dct2.is_real_fft());
        assert!(Kind::C2R.is_real_fft() && !Kind::C2R.is_trig());
        assert!(!Kind::C2C.is_trig() && !Kind::C2C.is_real_fft());
    }

    #[test]
    fn strategy_defaults_parses_and_validates() {
        let t = Transform::new(&[12, 12]);
        assert_eq!(t.strategy, DistStrategy::Gathered);
        // Zig-zag is a wrapper-pass strategy: meaningless for c2c.
        assert!(Transform::new(&[12, 12]).zigzag().validate().is_err());
        assert!(Transform::new(&[12, 12]).dct2().zigzag().validate().is_ok());
        assert!(Transform::new(&[12, 16]).r2c().zigzag().validate().is_ok());
        // The core descriptor never inherits the strategy (it has no
        // wrapper passes), so core plans stay shareable.
        let t = Transform::new(&[12, 12]).dct2().zigzag();
        assert_eq!(t.complex_core().strategy, DistStrategy::Gathered);
        for s in [DistStrategy::Gathered, DistStrategy::ZigZag] {
            assert_eq!(DistStrategy::parse(s.name()), Some(s));
        }
        assert_eq!(DistStrategy::parse("nope"), None);
    }

    #[test]
    fn normalization_scales() {
        assert_eq!(Normalization::None.scale(64), 1.0);
        assert_eq!(Normalization::ByN.scale(64), 1.0 / 64.0);
        assert!((Normalization::Unitary.scale(64) - 0.125).abs() < 1e-15);
    }
}
