//! The [`Transform`] descriptor: everything that identifies a
//! distributed multidimensional FFT *before* an algorithm is chosen —
//! shape, processor grid (explicit or auto-chosen), direction,
//! normalization, and batch count.
//!
//! The descriptor is plain data (`Eq + Hash`), which is what lets
//! [`super::PlanCache`] key plans by it.

use std::sync::Arc;

use crate::fft::Direction;

use super::error::FftError;
use super::plan::{plan, Algorithm, PlannedFft};

/// Output scaling, applied uniformly for every algorithm and direction.
///
/// The raw transforms (like FFTW's) are unnormalized: a forward followed
/// by an inverse multiplies the data by `N`. The descriptor makes the
/// convention explicit instead of leaving callers to hand-divide:
///
/// - [`Normalization::None`]: no scaling (FFTW default);
/// - [`Normalization::Unitary`]: `1/sqrt(N)` — forward and inverse both
///   unitary, so any forward/inverse pair round-trips;
/// - [`Normalization::ByN`]: `1/N` — the classic inverse-transform
///   scaling; `Forward` with `None` then `Inverse` with `ByN` is the
///   identity.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum Normalization {
    None,
    Unitary,
    ByN,
}

impl Normalization {
    /// The scale factor for an `n`-element transform.
    pub fn scale(self, n: usize) -> f64 {
        match self {
            Normalization::None => 1.0,
            Normalization::Unitary => 1.0 / (n as f64).sqrt(),
            Normalization::ByN => 1.0 / n as f64,
        }
    }
}

/// Processor-grid request: either an explicit per-axis grid or a total
/// processor count resolved per algorithm (via
/// [`crate::fftu::choose_grid`] for the cyclic algorithms).
#[derive(Clone, Debug, PartialEq, Eq, Hash)]
pub enum Grid {
    /// `p` total processors; the planner picks the per-axis split.
    Auto { p: usize },
    /// Explicit per-axis processor counts (cyclic-family algorithms) —
    /// its product is the processor count for the slab/pencil/brick
    /// algorithms, which place processors themselves.
    Explicit(Vec<usize>),
}

impl Grid {
    /// Total processor count this request asks for.
    pub fn procs(&self) -> usize {
        match self {
            Grid::Auto { p } => *p,
            Grid::Explicit(g) => g.iter().product(),
        }
    }
}

/// Descriptor of one (possibly batched) distributed FFT.
///
/// Built with the fluent constructors and handed to
/// [`Transform::plan`] / [`super::plan`] / [`super::PlanCache::plan`]:
///
/// ```
/// use fftu::api::{Algorithm, Normalization, Transform};
/// let t = Transform::new(&[16, 16])
///     .procs(4)
///     .inverse()
///     .normalization(Normalization::ByN)
///     .batch(2);
/// assert_eq!(t.total(), 256);
/// assert!(t.plan(Algorithm::Fftu).is_ok());
/// ```
#[derive(Clone, Debug, PartialEq, Eq, Hash)]
pub struct Transform {
    /// Global array shape `n_1 x ... x n_d`.
    pub shape: Vec<usize>,
    /// Processor grid request.
    pub grid: Grid,
    /// Transform direction (`Forward` is `e^{-2 pi i jk/n}`).
    pub direction: Direction,
    /// Output scaling.
    pub normalization: Normalization,
    /// Number of independent transforms per [`super::DistFft::execute_batch`]
    /// call; the input buffer holds `batch` arrays back to back.
    pub batch: usize,
}

impl Transform {
    /// A forward, unnormalized, single transform on one processor.
    pub fn new(shape: &[usize]) -> Self {
        Transform {
            shape: shape.to_vec(),
            grid: Grid::Auto { p: 1 },
            direction: Direction::Forward,
            normalization: Normalization::None,
            batch: 1,
        }
    }

    /// Use an explicit per-axis processor grid.
    pub fn grid(mut self, grid: &[usize]) -> Self {
        self.grid = Grid::Explicit(grid.to_vec());
        self
    }

    /// Use `p` total processors, letting the planner pick the split.
    pub fn procs(mut self, p: usize) -> Self {
        self.grid = Grid::Auto { p };
        self
    }

    pub fn direction(mut self, dir: Direction) -> Self {
        self.direction = dir;
        self
    }

    pub fn forward(self) -> Self {
        self.direction(Direction::Forward)
    }

    pub fn inverse(self) -> Self {
        self.direction(Direction::Inverse)
    }

    pub fn normalization(mut self, norm: Normalization) -> Self {
        self.normalization = norm;
        self
    }

    pub fn batch(mut self, batch: usize) -> Self {
        self.batch = batch;
        self
    }

    /// Elements per transform.
    pub fn total(&self) -> usize {
        self.shape.iter().product()
    }

    /// Structural validation shared by every algorithm (the per-axis
    /// divisibility rules are the algorithms' own, checked at plan time).
    pub fn validate(&self) -> Result<(), FftError> {
        if self.shape.is_empty() {
            return Err(FftError::BadDescriptor { reason: "shape must have at least one axis".into() });
        }
        if let Some(axis) = self.shape.iter().position(|&n| n == 0) {
            return Err(FftError::AxisConstraint { axis, n: 0, p: 0, requires: "n_l >= 1" });
        }
        if self.batch == 0 {
            return Err(FftError::BadDescriptor { reason: "batch must be >= 1".into() });
        }
        match &self.grid {
            Grid::Auto { p: 0 } => {
                Err(FftError::BadDescriptor { reason: "processor count must be >= 1".into() })
            }
            Grid::Explicit(g) if g.len() != self.shape.len() => {
                Err(FftError::RankMismatch { shape: self.shape.len(), grid: g.len() })
            }
            Grid::Explicit(g) => match g.iter().position(|&p| p == 0) {
                Some(axis) => Err(FftError::AxisConstraint {
                    axis,
                    n: self.shape[axis],
                    p: 0,
                    requires: "p_l >= 1",
                }),
                None => Ok(()),
            },
            _ => Ok(()),
        }
    }

    /// Plan this descriptor with `algo` — shorthand for
    /// [`super::plan`]`(algo, self)`.
    pub fn plan(&self, algo: Algorithm) -> Result<Arc<PlannedFft>, FftError> {
        plan(algo, self)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn builder_defaults_and_chaining() {
        let t = Transform::new(&[8, 4]);
        assert_eq!(t.grid, Grid::Auto { p: 1 });
        assert_eq!(t.direction, Direction::Forward);
        assert_eq!(t.normalization, Normalization::None);
        assert_eq!(t.batch, 1);
        let t = t.grid(&[2, 2]).inverse().normalization(Normalization::ByN).batch(3);
        assert_eq!(t.grid.procs(), 4);
        assert_eq!(t.direction, Direction::Inverse);
        assert_eq!(t.batch, 3);
        assert!(t.validate().is_ok());
    }

    #[test]
    fn validate_rejects_malformed_descriptors() {
        assert!(Transform::new(&[]).validate().is_err());
        assert!(Transform::new(&[8, 0]).validate().is_err());
        assert!(Transform::new(&[8]).batch(0).validate().is_err());
        assert!(Transform::new(&[8]).procs(0).validate().is_err());
        assert!(matches!(
            Transform::new(&[8, 8]).grid(&[2]).validate(),
            Err(FftError::RankMismatch { shape: 2, grid: 1 })
        ));
        assert!(Transform::new(&[8, 8]).grid(&[2, 0]).validate().is_err());
    }

    #[test]
    fn normalization_scales() {
        assert_eq!(Normalization::None.scale(64), 1.0);
        assert_eq!(Normalization::ByN.scale(64), 1.0 / 64.0);
        assert!((Normalization::Unitary.scale(64) - 0.125).abs() < 1e-15);
    }
}
