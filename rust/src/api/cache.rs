//! LRU plan cache keyed by the ([`Algorithm`], [`Transform`]) descriptor.
//!
//! Planning a distributed FFT is the expensive, fallible part: grid
//! resolution, divisibility validation, redistribution routing (O(N)
//! for the transpose-based baselines), and local FFT planning. Server
//! workloads repeat a small set of descriptors millions of times, so the
//! cache hands back the same `Arc<PlannedFft>` for a repeated descriptor
//! — the second request does **no planning work at all** (see the
//! pointer-identity test and `benches/plan_cache.rs`).
//!
//! Thread-safe: one `PlanCache` (e.g. in a `static` or an application
//! context) can serve concurrent request threads; plans themselves are
//! immutable and `Send + Sync`. Concurrent first requests for the same
//! descriptor may plan more than once, but every caller receives the
//! single cache-resident `Arc` (losers of the planning race discard
//! their copy), so pointer identity holds for identical descriptors —
//! the concurrency suite in `rust/tests/invariants.rs` hammers this.

use std::collections::HashMap;
use std::sync::{Arc, Mutex};

use super::error::FftError;
use super::plan::{plan, Algorithm, PlannedFft};
use super::transform::Transform;

#[derive(Clone, Debug, PartialEq, Eq, Hash)]
struct Key {
    algo: Algorithm,
    t: Transform,
}

struct State {
    map: HashMap<Key, Arc<PlannedFft>>,
    /// Recency list, least-recently-used first.
    order: Vec<Key>,
    hits: u64,
    misses: u64,
}

/// Point-in-time [`PlanCache`] counters (see [`PlanCache::stats`]).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct CacheStats {
    /// Requests answered from the cache (zero planning work).
    pub hits: u64,
    /// Requests that had to plan (and, racing aside, inserted).
    pub misses: u64,
    /// Plans currently resident.
    pub len: usize,
    /// Maximum resident plans before LRU eviction.
    pub capacity: usize,
}

impl CacheStats {
    /// Fraction of requests served without planning, in `[0, 1]`.
    /// An untouched cache has served nothing, so its rate is `0.0` —
    /// not `0/0` (which `cli run --verbose` would print as `NaN%`).
    pub fn hit_rate(&self) -> f64 {
        let total = self.hits + self.misses;
        if total == 0 {
            0.0
        } else {
            self.hits as f64 / total as f64
        }
    }
}

/// A bounded, least-recently-used cache of [`PlannedFft`]s.
pub struct PlanCache {
    capacity: usize,
    state: Mutex<State>,
}

impl std::fmt::Debug for PlanCache {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("PlanCache")
            .field("capacity", &self.capacity)
            .finish_non_exhaustive()
    }
}

impl PlanCache {
    /// A cache holding at most `capacity` plans (at least 1).
    pub fn new(capacity: usize) -> Self {
        PlanCache {
            capacity: capacity.max(1),
            state: Mutex::new(State {
                map: HashMap::new(),
                order: Vec::new(),
                hits: 0,
                misses: 0,
            }),
        }
    }

    /// Return the cached plan for this exact descriptor, or plan it and
    /// cache the result (evicting the least-recently-used entry when
    /// full). Planning errors are not cached.
    pub fn plan(&self, algo: Algorithm, t: &Transform) -> Result<Arc<PlannedFft>, FftError> {
        let key = Key { algo, t: t.clone() };
        {
            let mut st = self.state.lock().unwrap();
            if let Some(found) = st.map.get(&key).cloned() {
                st.hits += 1;
                if let Some(pos) = st.order.iter().position(|k| *k == key) {
                    st.order.remove(pos);
                }
                st.order.push(key);
                return Ok(found);
            }
        }
        // Plan outside the lock: planning can be expensive and must not
        // serialize unrelated descriptors.
        let planned = plan(algo, t)?;
        let mut st = self.state.lock().unwrap();
        st.misses += 1;
        if let Some(existing) = st.map.get(&key).cloned() {
            // Lost a planning race: another thread inserted this
            // descriptor while we were planning. Return the resident
            // plan (discarding ours) so identical descriptors are always
            // pointer-identical, no matter how they interleave.
            if let Some(pos) = st.order.iter().position(|k| *k == key) {
                st.order.remove(pos);
            }
            st.order.push(key);
            return Ok(existing);
        }
        if st.map.len() >= self.capacity {
            let oldest = st.order.remove(0);
            st.map.remove(&oldest);
        }
        st.map.insert(key.clone(), planned.clone());
        st.order.push(key);
        Ok(planned)
    }

    pub fn capacity(&self) -> usize {
        self.capacity
    }

    pub fn len(&self) -> usize {
        self.state.lock().unwrap().map.len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Cache hits so far (a hit means zero planning work was done).
    pub fn hits(&self) -> u64 {
        self.state.lock().unwrap().hits
    }

    /// Cache misses so far (each miss planned exactly once).
    pub fn misses(&self) -> u64 {
        self.state.lock().unwrap().misses
    }

    /// One consistent snapshot of the cache counters (hits and misses
    /// read under a single lock, so `hits + misses` equals the number of
    /// `plan` calls that returned). `cli run --verbose` prints this for
    /// perf debugging; services can export it to their metrics.
    pub fn stats(&self) -> CacheStats {
        let st = self.state.lock().unwrap();
        CacheStats {
            hits: st.hits,
            misses: st.misses,
            len: st.map.len(),
            capacity: self.capacity,
        }
    }

    /// Drop every cached plan and reset the counters.
    pub fn clear(&self) {
        let mut st = self.state.lock().unwrap();
        st.map.clear();
        st.order.clear();
        st.hits = 0;
        st.misses = 0;
    }
}

impl Default for PlanCache {
    /// A reasonable server default: 32 resident plans.
    fn default() -> Self {
        PlanCache::new(32)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::api::Normalization;

    #[test]
    fn second_request_reuses_the_same_plan() {
        let cache = PlanCache::new(4);
        let t = Transform::new(&[16, 16]).procs(4);
        let a = cache.plan(Algorithm::Fftu, &t).unwrap();
        let b = cache.plan(Algorithm::Fftu, &t).unwrap();
        // Pointer identity: the second call did no planning work.
        assert!(Arc::ptr_eq(&a, &b));
        assert_eq!(cache.hits(), 1);
        assert_eq!(cache.misses(), 1);
        assert_eq!(cache.len(), 1);
        let stats = cache.stats();
        assert_eq!(stats, CacheStats { hits: 1, misses: 1, len: 1, capacity: 4 });
        assert!((stats.hit_rate() - 0.5).abs() < 1e-12);
    }

    #[test]
    fn stats_snapshot_is_consistent() {
        let cache = PlanCache::new(2);
        assert_eq!(cache.stats(), CacheStats { hits: 0, misses: 0, len: 0, capacity: 2 });
        assert_eq!(cache.stats().hit_rate(), 0.0);
        let t = Transform::new(&[16, 16]).procs(4);
        for _ in 0..5 {
            cache.plan(Algorithm::Fftu, &t).unwrap();
        }
        let s = cache.stats();
        assert_eq!(s.hits + s.misses, 5);
        assert_eq!(s.misses, 1);
        cache.clear();
        assert_eq!(cache.stats(), CacheStats { hits: 0, misses: 0, len: 0, capacity: 2 });
    }

    #[test]
    fn fresh_cache_hit_rate_is_zero_and_finite() {
        // Regression: 0 hits / 0 misses must not read as a perfect (or
        // NaN) hit rate — nothing has been served yet.
        let rate = PlanCache::new(4).stats().hit_rate();
        assert!(rate.is_finite());
        assert_eq!(rate, 0.0);
    }

    #[test]
    fn different_descriptors_plan_separately() {
        let cache = PlanCache::new(4);
        let t = Transform::new(&[16, 16]).procs(4);
        let a = cache.plan(Algorithm::Fftu, &t).unwrap();
        let b = cache.plan(Algorithm::Popovici, &t).unwrap();
        let c = cache
            .plan(Algorithm::Fftu, &t.clone().normalization(Normalization::ByN))
            .unwrap();
        assert!(!Arc::ptr_eq(&a, &b));
        assert!(!Arc::ptr_eq(&a, &c));
        assert_eq!(cache.misses(), 3);
        assert_eq!(cache.len(), 3);
    }

    #[test]
    fn lru_evicts_oldest_not_hottest() {
        let cache = PlanCache::new(2);
        let t1 = Transform::new(&[16, 16]).procs(2);
        let t2 = Transform::new(&[16, 16]).procs(4);
        let t3 = Transform::new(&[16, 16]).procs(8);
        let a1 = cache.plan(Algorithm::Fftu, &t1).unwrap();
        let _ = cache.plan(Algorithm::Fftu, &t2).unwrap();
        // Touch t1 so t2 is the LRU entry, then insert t3.
        let a1_again = cache.plan(Algorithm::Fftu, &t1).unwrap();
        assert!(Arc::ptr_eq(&a1, &a1_again));
        let _ = cache.plan(Algorithm::Fftu, &t3).unwrap();
        assert_eq!(cache.len(), 2);
        // t1 must still be resident (hit), t2 must have been evicted
        // (miss → replan).
        let hits_before = cache.hits();
        let _ = cache.plan(Algorithm::Fftu, &t1).unwrap();
        assert_eq!(cache.hits(), hits_before + 1);
        let misses_before = cache.misses();
        let _ = cache.plan(Algorithm::Fftu, &t2).unwrap();
        assert_eq!(cache.misses(), misses_before + 1);
    }

    #[test]
    fn errors_are_not_cached() {
        let cache = PlanCache::new(2);
        let bad = Transform::new(&[15, 15]).procs(4); // no grid with p_l^2 | 15
        assert!(cache.plan(Algorithm::Fftu, &bad).is_err());
        assert_eq!(cache.len(), 0);
        assert_eq!(cache.misses(), 0);
    }
}
