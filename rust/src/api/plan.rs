//! Plan-time validation and the unified execute path: the [`Algorithm`]
//! enum, the [`DistFft`] trait, and [`plan`], which turns a
//! ([`Algorithm`], [`Transform`]) pair into a reusable [`PlannedFft`].
//!
//! Planning does all the expensive, fallible work once — grid
//! resolution, divisibility checks, distribution schedules, compiled
//! redistributions, local FFT plans — so execution is infallible apart
//! from input-length checks and can be repeated (and batched) with no
//! replanning. [`super::PlanCache`] builds on this split.

use std::sync::Arc;

use crate::analysis::{self, extract, RecordingCtx, Schedule, ScheduleReport};
use crate::baselines::{HefftePlan, OutputDist, PencilPlan, PopoviciPlan, SlabPlan};
use crate::bsp::CostReport;
use crate::costmodel;
use crate::fft::realnd::{
    pack_pairs, retangle_half_spectrum, unpack_pairs, untangle_half_spectrum, wrap_flops,
};
use crate::fft::trignd::{
    trig2_post, trig2_pre, trig2_tables, trig3_extract, trig3_pre, trig3_tables,
    trig_extract_flops, trig_wrap_flops,
};
use crate::fft::{C64, Planner};
use crate::fftu::{
    choose_grid, choose_grid_any, fftu_execute_batch_arena,
    fftu_execute_c2r_pairwise_batch_arena, fftu_execute_r2c_pairwise_batch_arena,
    fftu_execute_trig2_batch_arena, fftu_execute_trig2_zigzag_batch_arena,
    fftu_execute_trig3_batch_arena, fftu_execute_trig3_zigzag_batch_arena, fftu_pmax, zigzag,
    ExecArena, FftuPlan,
};

use super::error::FftError;
use super::transform::{DistStrategy, Grid, Kind, Transform};

/// Which distributed-FFT algorithm executes a [`Transform`].
///
/// All five run on the same BSP machine and sequential FFT substrate, so
/// choosing between them changes *communication structure only* — the
/// paper's subject.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum Algorithm {
    /// The paper's contribution: cyclic-to-cyclic, ONE all-to-all.
    Fftu,
    /// Parallel-FFTW slab decomposition (§1.2).
    Slab { out: OutputDist },
    /// PFFT r-dimensional block decomposition (§1.2).
    Pencil { r: usize, out: OutputDist },
    /// heFFTe brick-to-brick pipeline (§1.2).
    Heffte,
    /// Popovici et al. cyclic d-step (§1.2).
    Popovici,
    /// The autotuning planner: enumerate every feasible (algorithm,
    /// grid, strategy) candidate, price each with the analytic cost
    /// model against a [`crate::costmodel::Machine`], and plan the
    /// cheapest (the FFTW `Estimate` idiom; see
    /// [`super::planner`]). The winner is reachable through
    /// [`PlannedFft::chosen`].
    Auto,
}

impl Algorithm {
    /// Slab with the paper's default same-distribution output.
    pub fn slab() -> Self {
        Algorithm::Slab { out: OutputDist::Same }
    }

    /// Pencil with decomposition rank `r` and same-distribution output.
    pub fn pencil(r: usize) -> Self {
        Algorithm::Pencil { r, out: OutputDist::Same }
    }

    pub fn name(self) -> &'static str {
        match self {
            Algorithm::Fftu => "fftu",
            Algorithm::Slab { .. } => "slab",
            Algorithm::Pencil { .. } => "pencil",
            Algorithm::Heffte => "heffte",
            Algorithm::Popovici => "popovici",
            Algorithm::Auto => "auto",
        }
    }

    /// Parse a CLI-style name; `pencil` defaults to `r = 2` capped at
    /// `d - 1` when the shape rank is known to the caller.
    pub fn parse(s: &str) -> Option<Algorithm> {
        match s {
            "fftu" => Some(Algorithm::Fftu),
            "slab" => Some(Algorithm::slab()),
            "pencil" => Some(Algorithm::pencil(2)),
            "heffte" => Some(Algorithm::Heffte),
            "popovici" => Some(Algorithm::Popovici),
            "auto" => Some(Algorithm::Auto),
            _ => None,
        }
    }

    /// Documented communication-superstep count for a d-dimensional
    /// transform — the paper's headline comparison (§1.2, Eq. 2.12).
    pub fn comm_supersteps(self, d: usize) -> usize {
        match self {
            Algorithm::Fftu => 1,
            Algorithm::Slab { out } => 1 + usize::from(out == OutputDist::Same),
            Algorithm::Pencil { r, out } => {
                // ceil(r / (d-r)) for a valid 1 <= r < d; clamp the span
                // so an invalid r (which `plan` rejects) cannot divide by
                // zero here.
                let span = d.saturating_sub(r).max(1);
                let stages = (r + span - 1) / span;
                stages + usize::from(out == OutputDist::Same)
            }
            Algorithm::Heffte => d + 1,
            Algorithm::Popovici => d,
            // Before planning, Auto's count is whatever the planner
            // picks; the worst candidate's d + 1 (heFFTe) is the only
            // descriptor-independent bound. A planned Auto reports its
            // real count through `PlannedFft::chosen`, and `analyze`
            // verifies against the chosen algorithm, not this bound.
            Algorithm::Auto => d + 1,
        }
    }
}

/// Result of executing a planned transform: the output array(s), back to
/// back for a batch, plus the exact BSP cost ledger of the run.
#[derive(Debug)]
pub struct Execution {
    pub output: Vec<C64>,
    pub report: CostReport,
}

/// Result of an execution with real output ([`Kind::C2R`] and the trig
/// kinds): real output array(s), back to back for a batch, plus the
/// ledger.
#[derive(Debug)]
pub struct RealExecution {
    pub output: Vec<f64>,
    pub report: CostReport,
}

/// Typed input buffer for the unified [`DistFft::execute`] front door:
/// one enum over the two input domains, validated against the plan's
/// [`Kind`] at execute time. `Complex` feeds [`Kind::C2C`] (time-domain
/// samples) and [`Kind::C2R`] (the Hermitian half-spectrum); `Real`
/// feeds [`Kind::R2C`] and every trig kind. `From` impls cover slices
/// and `&Vec`, so concrete-plan callers just write `plan.execute(&x)`.
#[derive(Clone, Copy, Debug)]
pub enum BatchIo<'a> {
    /// Complex samples: C2C input, or a C2R plan's packed half-spectrum
    /// (`spectrum_total()` bins per item).
    Complex(&'a [C64]),
    /// Real samples: R2C input, or any trig kind's input (`total()`
    /// reals per item).
    Real(&'a [f64]),
}

impl BatchIo<'_> {
    /// The kinds this buffer domain can feed — the `expected` field of
    /// the typed mismatch error.
    fn expected_kinds(&self) -> &'static str {
        match self {
            BatchIo::Complex(_) => "c2c|c2r",
            BatchIo::Real(_) => "r2c|dct2|dct3|dst2|dst3",
        }
    }
}

impl<'a> From<&'a [C64]> for BatchIo<'a> {
    fn from(buf: &'a [C64]) -> Self {
        BatchIo::Complex(buf)
    }
}

impl<'a> From<&'a Vec<C64>> for BatchIo<'a> {
    fn from(buf: &'a Vec<C64>) -> Self {
        BatchIo::Complex(buf)
    }
}

impl<'a> From<&'a [f64]> for BatchIo<'a> {
    fn from(buf: &'a [f64]) -> Self {
        BatchIo::Real(buf)
    }
}

impl<'a> From<&'a Vec<f64>> for BatchIo<'a> {
    fn from(buf: &'a Vec<f64>) -> Self {
        BatchIo::Real(buf)
    }
}

impl<'a, const N: usize> From<&'a [C64; N]> for BatchIo<'a> {
    fn from(buf: &'a [C64; N]) -> Self {
        BatchIo::Complex(buf)
    }
}

impl<'a, const N: usize> From<&'a [f64; N]> for BatchIo<'a> {
    fn from(buf: &'a [f64; N]) -> Self {
        BatchIo::Real(buf)
    }
}

/// Result of the unified [`DistFft::execute`]: the output lands in the
/// domain the plan's [`Kind`] produces — `Complex` for C2C and R2C
/// (half-spectrum out), `Real` for C2R and the trig kinds. The variant
/// is fully determined by the kind, so unwrapping with [`Self::complex`]
/// / [`Self::real`] next to the `plan(...)` call can never panic.
#[derive(Debug)]
pub enum BatchOut {
    /// Complex output: a C2C transform, or an R2C half-spectrum.
    Complex(Execution),
    /// Real output: a C2R inverse, or trig coefficients.
    Real(RealExecution),
}

impl BatchOut {
    /// The BSP cost ledger of the run, whichever domain it produced.
    pub fn report(&self) -> &CostReport {
        match self {
            BatchOut::Complex(exec) => &exec.report,
            BatchOut::Real(exec) => &exec.report,
        }
    }

    /// Consume the result, keeping only the ledger — for callers that
    /// time or audit a run without reading the output.
    pub fn into_report(self) -> CostReport {
        match self {
            BatchOut::Complex(exec) => exec.report,
            BatchOut::Real(exec) => exec.report,
        }
    }

    /// Unwrap the complex-domain result (C2C / R2C plans).
    ///
    /// # Panics
    /// If the plan's kind produces real output (C2R / trig).
    pub fn complex(self) -> Execution {
        match self {
            BatchOut::Complex(exec) => exec,
            BatchOut::Real(_) => {
                panic!("complex output requested from a real-output (c2r/trig) execution")
            }
        }
    }

    /// Unwrap the real-domain result (C2R / trig plans).
    ///
    /// # Panics
    /// If the plan's kind produces complex output (C2C / R2C).
    pub fn real(self) -> RealExecution {
        match self {
            BatchOut::Real(exec) => exec,
            BatchOut::Complex(_) => {
                panic!("real output requested from a complex-output (c2c/r2c) execution")
            }
        }
    }
}

/// The unified plan/execute interface every algorithm implements (via
/// [`PlannedFft`]). Plans are immutable and `Send + Sync`: share one
/// behind an `Arc` and execute from as many threads as you like.
pub trait DistFft: Send + Sync {
    /// The algorithm this plan executes.
    fn algorithm(&self) -> Algorithm;
    /// The descriptor this plan was built from.
    fn transform(&self) -> &Transform;
    /// Total processors the plan runs on.
    fn procs(&self) -> usize;
    /// The resolved per-axis cyclic grid (FFTU/Popovici), if any.
    fn grid(&self) -> Option<&[usize]>;
    /// The unified batch front door: execute the descriptor's `batch`
    /// transforms (whatever the plan's [`Kind`]) from one contiguous
    /// typed buffer, amortizing per-rank state across the batch — and,
    /// for FFTU batches of two or more, software-pipelining entry
    /// `i + 1`'s pack/superstep-0 compute under entry `i`'s in-flight
    /// all-to-all (see `docs/ARCHITECTURE.md`, "Pipelined batching").
    ///
    /// The input domain is checked against the kind: `Complex` feeds
    /// C2C/C2R, `Real` feeds R2C/trig; anything else is a typed
    /// [`FftError::KindMismatch`]. Concrete [`PlannedFft`] callers get
    /// `impl Into<BatchIo>` sugar (`plan.execute(&x)`); through
    /// `dyn DistFft`, wrap explicitly (`BatchIo::Complex(&x)`).
    fn execute(&self, io: BatchIo<'_>) -> Result<BatchOut, FftError>;
    /// One-sample convenience wrapper over [`Self::execute`]: run ONE
    /// transform (one item's worth of input) regardless of the
    /// descriptor's batch count.
    fn execute_one(&self, io: BatchIo<'_>) -> Result<BatchOut, FftError>;
    /// Execute the descriptor's `batch` C2C transforms.
    #[deprecated(since = "0.3.0", note = "use `execute(&x)` — the unified `BatchIo` front door")]
    fn execute_batch(&self, input: &[C64]) -> Result<Execution, FftError>;
    /// Execute ONE R2C transform: `total()` reals in, `spectrum_total()`
    /// Hermitian half-spectrum bins out.
    #[deprecated(since = "0.3.0", note = "use `execute_one(&x).complex()`")]
    fn execute_r2c(&self, input: &[f64]) -> Result<Execution, FftError>;
    /// Execute the descriptor's `batch` R2C transforms back to back.
    #[deprecated(since = "0.3.0", note = "use `execute(&x).complex()`")]
    fn execute_r2c_batch(&self, input: &[f64]) -> Result<Execution, FftError>;
    /// Execute ONE C2R transform: `spectrum_total()` half-spectrum bins
    /// in, `total()` reals out.
    #[deprecated(since = "0.3.0", note = "use `execute_one(&x).real()`")]
    fn execute_c2r(&self, input: &[C64]) -> Result<RealExecution, FftError>;
    /// Execute the descriptor's `batch` C2R transforms back to back.
    #[deprecated(since = "0.3.0", note = "use `execute(&x).real()`")]
    fn execute_c2r_batch(&self, input: &[C64]) -> Result<RealExecution, FftError>;
    /// Execute ONE trig transform (any of DCT-II/III, DST-II/III —
    /// whichever [`Kind`] the plan was built for): `total()` reals in,
    /// `total()` real coefficients out.
    #[deprecated(since = "0.3.0", note = "use `execute_one(&x).real()`")]
    fn execute_trig(&self, input: &[f64]) -> Result<RealExecution, FftError>;
    /// Execute the descriptor's `batch` trig transforms back to back.
    #[deprecated(since = "0.3.0", note = "use `execute(&x).real()`")]
    fn execute_trig_batch(&self, input: &[f64]) -> Result<RealExecution, FftError>;
}

enum Inner {
    /// FFTU with its persistent [`ExecArena`]: per-rank workers (twiddle
    /// tables, packet buffers, scratch) are built on the first execute
    /// and live as long as the plan — a cached plan's steady-state
    /// executes do zero per-rank allocation.
    Fftu { plan: Arc<FftuPlan>, arena: ExecArena },
    Slab(SlabPlan),
    Pencil(PencilPlan),
    Heffte(HefftePlan),
    Popovici(PopoviciPlan),
    /// R2C/C2R and the trig kinds: the complex core planned on the
    /// packed half shape (real FFT) or the full shape (trig);
    /// pack/untangle or permute/phase-combine wrap around it at execute
    /// time. Works for every algorithm, so all five get real and trig
    /// paths for free — and FFTU's wrappers additionally fold the
    /// Makhoul permutation into its cyclic scatter/gather. For trig
    /// kinds, `trig` holds the per-axis quarter-wave tables
    /// (`sum_l n_l` words), built once here so steady-state executes
    /// evaluate no trig functions. Under [`DistStrategy::ZigZag`],
    /// `r2c_tw` additionally holds the untangle/retangle twiddles the
    /// rank-local r2c/c2r passes need (`h + 1` forward, `h` conjugated
    /// inverse) — also plan-time, for the same reason.
    Real { core: Arc<PlannedFft>, trig: Option<Vec<Vec<C64>>>, r2c_tw: Option<Vec<C64>> },
    /// [`Algorithm::Auto`]: the autotuning planner's winner, a complete
    /// plan for the same descriptor semantics with the concrete
    /// (algorithm, grid, strategy) substituted. Every execute and the
    /// verifier delegate to it wholesale; the scored candidate table is
    /// kept for reporting (`cli run --algo auto --verbose`), and
    /// `chosen_idx` (the winner's row in that table) lets a failed
    /// session fail over to the next-cheapest candidate.
    Auto {
        chosen: Arc<PlannedFft>,
        table: Vec<super::planner::ScoredCandidate>,
        chosen_idx: usize,
    },
}

/// A validated, reusable plan binding a [`Transform`] to an
/// [`Algorithm`]. Built by [`plan`] (or [`Transform::plan`] /
/// [`super::PlanCache::plan`]); executing it never replans.
pub struct PlannedFft {
    algo: Algorithm,
    t: Transform,
    grid: Option<Vec<usize>>,
    p: usize,
    inner: Inner,
}

impl std::fmt::Debug for PlannedFft {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("PlannedFft")
            .field("algo", &self.algo)
            .field("shape", &self.t.shape)
            .field("procs", &self.p)
            .finish_non_exhaustive()
    }
}

/// Resolve the per-axis cyclic grid for the cyclic-family algorithms
/// that require the single-all-to-all rule `p_l^2 | n_l` (Popovici).
fn resolve_cyclic_grid(t: &Transform) -> Result<Vec<usize>, FftError> {
    match &t.grid {
        Grid::Explicit(g) => Ok(g.clone()),
        Grid::Auto { p } => choose_grid(&t.shape, *p)
            .ok_or(FftError::NoValidGrid { p: *p, pmax: fftu_pmax(&t.shape) }),
    }
}

/// Resolve the per-axis grid for FFTU, which additionally accepts
/// beyond-sqrt(N) grids via the group-cyclic ladder: `Auto { p }` first
/// tries the single-all-to-all grids, then any ladder-feasible
/// factorization ([`choose_grid_any`]). The `pmax` in the error remains
/// the single-all-to-all ceiling — the documented Alg. 3.1 bound.
fn resolve_fftu_grid(t: &Transform) -> Result<Vec<usize>, FftError> {
    match &t.grid {
        Grid::Explicit(g) => Ok(g.clone()),
        Grid::Auto { p } => choose_grid_any(&t.shape, *p)
            .ok_or(FftError::NoValidGrid { p: *p, pmax: fftu_pmax(&t.shape) }),
    }
}

/// Validate `t` and build a reusable plan for `algo`.
pub fn plan(algo: Algorithm, t: &Transform) -> Result<Arc<PlannedFft>, FftError> {
    t.validate()?;
    if algo == Algorithm::Auto {
        // The planner owns the whole descriptor (it enumerates grids
        // AND strategies), so Auto is resolved before the real-kind
        // recursion below — the winner it returns is a complete plan.
        return super::planner::plan_auto(
            t,
            &costmodel::Machine::planner_default(),
            super::planner::PlannerMode::Estimate,
        );
    }
    if t.kind != Kind::C2C {
        // Real kinds plan the complex core on the packed half shape
        // (the grid resolves there, so the per-axis divisibility rules
        // apply against n_d/2 on the last axis); trig kinds plan it on
        // the full shape (the Makhoul permutation reorders, it does not
        // pack, so the c2c grid rules carry over unchanged) and
        // precompute their quarter-wave tables here, at plan time.
        let core = plan(algo, &t.complex_core())?;
        let grid = core.grid.clone();
        let p = core.p;
        if t.strategy == DistStrategy::ZigZag {
            // The rank-local passes are implemented on FFTU's cyclic
            // core (they reuse its pairwise-exchange/worker machinery);
            // the baselines keep the facade-level passes.
            if !matches!(algo, Algorithm::Fftu) {
                return Err(FftError::Unsupported {
                    reason: format!(
                        "the zig-zag (rank-local) strategy is implemented for fftu only, \
                         got {}",
                        algo.name()
                    ),
                });
            }
            if let Inner::Fftu { plan, .. } = &core.inner {
                // The rank-local combine passes assume the cyclic output
                // placement of the single all-to-all; a beyond-sqrt(N)
                // core compiles the group-cyclic ladder instead, whose
                // output placement they cannot consume. Reject at plan
                // time with the same error kind the engines raise.
                if plan.is_ladder() {
                    return Err(FftError::Unsupported {
                        reason: format!(
                            "the zig-zag (rank-local) strategy requires the \
                             single-all-to-all core (p_l^2 | n_l); this grid needs \
                             the k = {} group-cyclic ladder — use \
                             DistStrategy::Gathered",
                            plan.comm_stages()
                        ),
                    });
                }
            }
            if t.kind.is_trig() {
                // The mirror folding needs whole 2 p_l periods on every
                // shared axis (on top of the plan's own p_l^2 | n_l).
                let resolved = grid.as_deref().expect("fftu cores always resolve a grid");
                zigzag::validate_zigzag_axes(&t.shape, resolved)?;
            }
        }
        let trig = match t.kind {
            Kind::Dct2 | Kind::Dst2 => Some(trig2_tables(&t.shape)),
            Kind::Dct3 | Kind::Dst3 => Some(trig3_tables(&t.shape)),
            _ => None,
        };
        let r2c_tw = if t.strategy == DistStrategy::ZigZag {
            let d = t.shape.len();
            let n_last = t.shape[d - 1];
            let h = n_last / 2;
            match t.kind {
                // Same constructions as the facade's untangle/retangle,
                // so the rank-local passes stay bit-identical to them.
                Kind::R2C => Some((0..=h).map(|k| C64::root_of_unity(n_last, k)).collect()),
                Kind::C2R => {
                    Some((0..h).map(|k| C64::root_of_unity(n_last, k).conj()).collect())
                }
                _ => None,
            }
        } else {
            None
        };
        let inner = Inner::Real { core, trig, r2c_tw };
        return Ok(Arc::new(PlannedFft { algo, t: t.clone(), grid, p, inner }));
    }
    let p = t.grid.procs();
    let (inner, grid, p) = match algo {
        Algorithm::Fftu => {
            let grid = resolve_fftu_grid(t)?;
            let planner = Planner::new();
            let plan = Arc::new(FftuPlan::new(&t.shape, &grid, &planner)?);
            let p = plan.num_procs();
            let arena = ExecArena::new(p);
            (Inner::Fftu { plan, arena }, Some(grid), p)
        }
        Algorithm::Slab { out } => (Inner::Slab(SlabPlan::new(&t.shape, p, out)?), None, p),
        Algorithm::Pencil { r, out } => {
            (Inner::Pencil(PencilPlan::new(&t.shape, r, p, out)?), None, p)
        }
        Algorithm::Heffte => (Inner::Heffte(HefftePlan::new(&t.shape, p)?), None, p),
        Algorithm::Popovici => {
            let grid = resolve_cyclic_grid(t)?;
            let plan = PopoviciPlan::new(&t.shape, &grid)?;
            let p = plan.num_procs();
            (Inner::Popovici(plan), Some(grid), p)
        }
        Algorithm::Auto => unreachable!("Auto is resolved by the planner above"),
    };
    Ok(Arc::new(PlannedFft { algo, t: t.clone(), grid, p, inner }))
}

impl PlannedFft {
    pub fn algorithm(&self) -> Algorithm {
        self.algo
    }

    pub fn transform(&self) -> &Transform {
        &self.t
    }

    pub fn procs(&self) -> usize {
        self.p
    }

    pub fn grid(&self) -> Option<&[usize]> {
        self.grid.as_deref()
    }

    /// For an [`Algorithm::Auto`] plan: the concrete plan the
    /// autotuning planner selected (its `algorithm()`, `grid()` and
    /// `transform().strategy` are the winning candidate). `None` for
    /// explicitly requested algorithms.
    pub fn chosen(&self) -> Option<&Arc<PlannedFft>> {
        match &self.inner {
            Inner::Auto { chosen, .. } => Some(chosen),
            _ => None,
        }
    }

    /// For an [`Algorithm::Auto`] plan: every candidate the planner
    /// priced, sorted cheapest-predicted first. `None` for explicitly
    /// requested algorithms.
    pub fn planner_table(&self) -> Option<&[super::planner::ScoredCandidate]> {
        match &self.inner {
            Inner::Auto { table, .. } => Some(table),
            _ => None,
        }
    }

    /// Wrap the planner's winner under the `Auto` descriptor so the
    /// [`super::PlanCache`] keys repeat requests on what the caller
    /// asked for (`Algorithm::Auto` + the original descriptor), not on
    /// what the planner resolved it to.
    pub(super) fn new_auto(
        t: Transform,
        chosen: Arc<PlannedFft>,
        table: Vec<super::planner::ScoredCandidate>,
        chosen_idx: usize,
    ) -> PlannedFft {
        PlannedFft {
            algo: Algorithm::Auto,
            grid: chosen.grid.clone(),
            p: chosen.p,
            inner: Inner::Auto { chosen, table, chosen_idx },
            t,
        }
    }

    /// Set the BSP session options (superstep deadline, fault
    /// injection, batch pipeline depth) used by subsequent executes of
    /// this plan — build them with
    /// [`ExecOptions::builder`](crate::bsp::ExecOptions::builder).
    /// Reaches through real/trig wrappers and Auto delegation to the
    /// arena that actually runs the SPMD sessions.
    pub fn set_exec_options(&self, opts: crate::bsp::ExecOptions) {
        match &self.inner {
            Inner::Fftu { arena, .. } => arena.set_exec_options(opts),
            Inner::Slab(plan) => plan.set_exec_options(opts),
            Inner::Pencil(plan) => plan.set_exec_options(opts),
            Inner::Heffte(plan) => plan.set_exec_options(opts),
            Inner::Popovici(plan) => plan.set_exec_options(opts),
            Inner::Real { core, .. } => core.set_exec_options(opts),
            Inner::Auto { chosen, .. } => chosen.set_exec_options(opts),
        }
    }

    /// Whether `e` is a runtime BSP session failure (as opposed to a
    /// plan-time or input-validation error) — the class the Auto
    /// failover below covers.
    fn is_session_failure(e: &FftError) -> bool {
        matches!(e, FftError::RankFailure { .. } | FftError::Timeout { .. })
    }

    /// One-shot failover for an [`Algorithm::Auto`] plan: after the
    /// chosen candidate's session fails, plan the next-cheapest
    /// candidate that still plans and run it ONCE (it starts from a
    /// fresh arena and default session options, so an injected fault
    /// bound to the failed plan does not follow it). If no alternative
    /// exists or the alternative also fails, the ORIGINAL error
    /// surfaces — failover is best-effort, never a loop.
    fn auto_failover<T>(
        &self,
        chosen_idx: usize,
        table: &[super::planner::ScoredCandidate],
        original: FftError,
        exec: impl Fn(&PlannedFft) -> Result<T, FftError>,
    ) -> Result<T, FftError> {
        for cand in &table[chosen_idx + 1..] {
            let Ok(alt) = plan(cand.algorithm, &cand.descriptor(&self.t)) else {
                continue;
            };
            return exec(&alt).map_err(|_| original);
        }
        Err(original)
    }

    /// The unified batch front door; see [`DistFft::execute`]. The
    /// `impl Into` sugar accepts `&[C64]`/`&[f64]` slices, `&Vec`s, and
    /// array refs directly, as well as an explicit [`BatchIo`].
    pub fn execute<'a>(&self, io: impl Into<BatchIo<'a>>) -> Result<BatchOut, FftError> {
        self.execute_io(io.into(), self.t.batch, "execute")
    }

    /// One-sample convenience wrapper; see [`DistFft::execute_one`].
    pub fn execute_one<'a>(&self, io: impl Into<BatchIo<'a>>) -> Result<BatchOut, FftError> {
        self.execute_io(io.into(), 1, "execute_one")
    }

    /// Kind-checked dispatch behind [`Self::execute`] /
    /// [`Self::execute_one`] and the deprecated kind-specific delegates:
    /// route the typed buffer to the executor the plan's kind needs, or
    /// reject the domain mismatch with a typed error.
    fn execute_io(
        &self,
        io: BatchIo<'_>,
        batch: usize,
        call: &'static str,
    ) -> Result<BatchOut, FftError> {
        match (io, self.t.kind) {
            (BatchIo::Complex(x), Kind::C2C) => self.run(x, batch).map(BatchOut::Complex),
            (BatchIo::Complex(x), Kind::C2R) => {
                self.run_c2r(x, batch, call).map(BatchOut::Real)
            }
            (BatchIo::Real(x), Kind::R2C) => self.run_r2c(x, batch, call).map(BatchOut::Complex),
            (BatchIo::Real(x), kind) if kind.is_trig() => {
                self.run_trig(x, batch, call).map(BatchOut::Real)
            }
            (io, kind) => Err(FftError::KindMismatch {
                kind: kind.name(),
                call,
                expected: io.expected_kinds(),
            }),
        }
    }

    /// Execute the descriptor's C2C batch; see [`DistFft::execute_batch`].
    #[deprecated(since = "0.3.0", note = "use `execute(&x)` — the unified `BatchIo` front door")]
    pub fn execute_batch(&self, input: &[C64]) -> Result<Execution, FftError> {
        self.ensure_kind(Kind::C2C, "execute_batch")?;
        Ok(self.execute_io(BatchIo::Complex(input), self.t.batch, "execute_batch")?.complex())
    }

    /// Execute ONE R2C transform; see [`DistFft::execute_r2c`].
    #[deprecated(since = "0.3.0", note = "use `execute_one(&x).complex()`")]
    pub fn execute_r2c(&self, input: &[f64]) -> Result<Execution, FftError> {
        self.ensure_kind(Kind::R2C, "execute_r2c")?;
        Ok(self.execute_io(BatchIo::Real(input), 1, "execute_r2c")?.complex())
    }

    /// Execute the descriptor's R2C batch; see [`DistFft::execute_r2c_batch`].
    #[deprecated(since = "0.3.0", note = "use `execute(&x).complex()`")]
    pub fn execute_r2c_batch(&self, input: &[f64]) -> Result<Execution, FftError> {
        self.ensure_kind(Kind::R2C, "execute_r2c_batch")?;
        Ok(self.execute_io(BatchIo::Real(input), self.t.batch, "execute_r2c_batch")?.complex())
    }

    /// Execute ONE C2R transform; see [`DistFft::execute_c2r`].
    #[deprecated(since = "0.3.0", note = "use `execute_one(&x).real()`")]
    pub fn execute_c2r(&self, input: &[C64]) -> Result<RealExecution, FftError> {
        self.ensure_kind(Kind::C2R, "execute_c2r")?;
        Ok(self.execute_io(BatchIo::Complex(input), 1, "execute_c2r")?.real())
    }

    /// Execute the descriptor's C2R batch; see [`DistFft::execute_c2r_batch`].
    #[deprecated(since = "0.3.0", note = "use `execute(&x).real()`")]
    pub fn execute_c2r_batch(&self, input: &[C64]) -> Result<RealExecution, FftError> {
        self.ensure_kind(Kind::C2R, "execute_c2r_batch")?;
        Ok(self.execute_io(BatchIo::Complex(input), self.t.batch, "execute_c2r_batch")?.real())
    }

    /// Execute ONE trig transform; see [`DistFft::execute_trig`].
    #[deprecated(since = "0.3.0", note = "use `execute_one(&x).real()`")]
    pub fn execute_trig(&self, input: &[f64]) -> Result<RealExecution, FftError> {
        self.ensure_trig("execute_trig")?;
        Ok(self.execute_io(BatchIo::Real(input), 1, "execute_trig")?.real())
    }

    /// Execute the descriptor's trig batch; see
    /// [`DistFft::execute_trig_batch`].
    #[deprecated(since = "0.3.0", note = "use `execute(&x).real()`")]
    pub fn execute_trig_batch(&self, input: &[f64]) -> Result<RealExecution, FftError> {
        self.ensure_trig("execute_trig_batch")?;
        Ok(self.execute_io(BatchIo::Real(input), self.t.batch, "execute_trig_batch")?.real())
    }

    fn ensure_kind(&self, expected: Kind, call: &'static str) -> Result<(), FftError> {
        if self.t.kind != expected {
            return Err(FftError::KindMismatch {
                kind: self.t.kind.name(),
                call,
                expected: expected.name(),
            });
        }
        Ok(())
    }

    /// [`Self::ensure_kind`] over the four trig kinds at once.
    fn ensure_trig(&self, call: &'static str) -> Result<(), FftError> {
        if !self.t.kind.is_trig() {
            return Err(FftError::KindMismatch {
                kind: self.t.kind.name(),
                call,
                expected: "dct2|dct3|dst2|dst3",
            });
        }
        Ok(())
    }

    /// The planned complex core of a real- or trig-kind plan.
    fn real_inner(&self) -> &Arc<PlannedFft> {
        match &self.inner {
            Inner::Real { core, .. } => core,
            _ => unreachable!("real/trig-kind plans always hold Inner::Real"),
        }
    }

    /// The plan-time quarter-wave tables of a trig-kind plan.
    fn trig_tables(&self) -> &[Vec<C64>] {
        match &self.inner {
            Inner::Real { trig: Some(tables), .. } => tables,
            _ => unreachable!("trig-kind plans precompute their tables"),
        }
    }

    /// The plan-time untangle/retangle twiddles of a zig-zag r2c/c2r plan.
    fn r2c_twiddles(&self) -> &[C64] {
        match &self.inner {
            Inner::Real { r2c_tw: Some(tw), .. } => tw,
            _ => unreachable!("zig-zag r2c/c2r plans precompute their twiddles"),
        }
    }

    /// The FFTU core plan + arena of a zig-zag-strategy wrapper plan
    /// (plan-time validation guarantees the core is FFTU).
    fn fftu_core(core: &PlannedFft) -> (&Arc<FftuPlan>, &ExecArena) {
        match &core.inner {
            Inner::Fftu { plan, arena } => (plan, arena),
            _ => unreachable!("zig-zag plans are fftu-only (validated at plan time)"),
        }
    }

    /// Statically verify this plan's communication protocol: extract
    /// the data-independent per-rank schedule of ONE batch item (no
    /// payload is touched — extraction is `O(d * p)` per rank, like
    /// [`crate::dist::analytic_h`]), build the matching analytic cost
    /// ledger, and run the [`crate::analysis`] lint suite over both.
    ///
    /// The returned [`ScheduleReport`] carries the schedule, the
    /// analytic ledger, and every lint verdict;
    /// [`ScheduleReport::passed`] is the overall answer and
    /// [`ScheduleReport::render`] the human-readable table the
    /// `cli analyze` command prints. For a batch plan the executed
    /// ledger repeats the core events per item; the schedule (like the
    /// analytic model) describes one item.
    pub fn analyze(&self) -> Result<ScheduleReport, FftError> {
        if let Inner::Auto { chosen, .. } = &self.inner {
            // Verify the schedule that will actually execute: the
            // winner's, under the winner's algorithm expectations.
            return chosen.analyze();
        }
        let schedule = Schedule::record(self.p, |rec| self.record_events(rec));
        let analytic = self.analytic_report()?;
        let expectations = self.expectations();
        let lints = analysis::verify(&schedule, &analytic, &expectations);
        Ok(ScheduleReport {
            algorithm: self.algo.name(),
            kind: self.t.kind.name(),
            strategy: self.t.strategy.name(),
            shape: self.t.shape.clone(),
            grid: self.grid.clone(),
            procs: self.p,
            expectations,
            schedule,
            analytic,
            lints,
        })
    }

    /// Statically verify the **software-pipelined batch** schedule of
    /// this plan: the depth-2 split-phase schedule the batch executors
    /// run for `batch` entries (entry `i + 1` packs and runs its
    /// flight-window compute while entry `i`'s packets are in flight
    /// between `exchange_start` and `exchange_finish`), checked by the
    /// full lint suite — including [`crate::analysis::Lint::SplitPhase`]
    /// pairing — against the per-item analytic ledger replayed in
    /// pipelined-executed order.
    ///
    /// Pipelining reorders supersteps but never changes what any entry
    /// charges, so the flow-conservation lint still proves
    /// `h == analytic_h` for every all-to-all, and the
    /// single-all-to-all invariant holds *per entry*: exactly `batch`
    /// collectives, every one labeled `fftu-alltoall`.
    ///
    /// Plans whose executors never pipeline (the baselines) and batches
    /// of fewer than two entries fall back to the per-item
    /// [`Self::analyze`].
    pub fn analyze_pipelined(&self, batch: usize) -> Result<ScheduleReport, FftError> {
        if let Inner::Auto { chosen, .. } = &self.inner {
            return chosen.analyze_pipelined(batch);
        }
        if batch < 2 || !matches!(self.inner, Inner::Fftu { .. } | Inner::Real { .. }) {
            return self.analyze();
        }
        let one = Schedule::record(self.p, |rec| self.record_events(rec));
        let Some((schedule, order)) =
            extract::pipeline(&one, batch, self.pipeline_flight_prefix())
        else {
            // Shapes the transform cannot pipeline execute sequentially.
            return self.analyze();
        };
        let one_report = self.analytic_report()?;
        if order.iter().any(|&j| j >= one_report.supersteps.len()) {
            // Structural drift between schedule and analytic ledger: the
            // per-item lint run reports it without an out-of-range replay.
            return self.analyze();
        }
        let analytic = extract::pipeline_analytic(&one_report, &order);
        let mut expectations = self.expectations();
        expectations.batch = batch;
        let lints = analysis::verify(&schedule, &analytic, &expectations);
        Ok(ScheduleReport {
            algorithm: self.algo.name(),
            kind: self.t.kind.name(),
            strategy: self.t.strategy.name(),
            shape: self.t.shape.clone(),
            grid: self.grid.clone(),
            procs: self.p,
            expectations,
            schedule,
            analytic,
            lints,
        })
    }

    /// How many leading in-session supersteps the pipelined batch
    /// drivers overlap with an in-flight exchange: superstep 0 for most
    /// kinds, only the trig phase pass for DCT3/DST3 zig-zag (the
    /// zig-zag conversion is pairwise and must wait for the finish), and
    /// nothing for zig-zag c2r, whose flight window only scatters the
    /// next entry's spectrum.
    fn pipeline_flight_prefix(&self) -> usize {
        if self.t.strategy == DistStrategy::ZigZag {
            match self.t.kind {
                Kind::C2R => 0,
                _ => 1,
            }
        } else {
            1
        }
    }

    /// What the verifier may assume from the algorithm choice: FFTU's
    /// single all-to-all (Alg. 3.1) — or, beyond sqrt(N), exactly the
    /// plan's `comm_stages()` group-cyclic ladder exchanges in stage
    /// order — or the baseline's documented collective count (§1.2)
    /// with no pairwise steps.
    fn expectations(&self) -> analysis::Expectations {
        let d = self.t.shape.len();
        let is_fftu = matches!(self.algo, Algorithm::Fftu);
        let ladder_stages = match &self.inner {
            Inner::Fftu { plan, .. } => plan.comm_stages(),
            Inner::Real { core, .. } => match &core.inner {
                Inner::Fftu { plan, .. } => plan.comm_stages(),
                _ => 1,
            },
            _ => 1,
        };
        analysis::Expectations {
            single_alltoall: is_fftu,
            collectives: if is_fftu { ladder_stages } else { self.algo.comm_supersteps(d) },
            batch: 1,
            ladder_stages,
        }
    }

    /// Narrate one rank's superstep events for ONE batch item, mirroring
    /// the executor dispatch in `run`/`run_r2c`/`run_c2r`/`run_trig`
    /// one-for-one (compute/comm labels in executed-ledger order, arena
    /// sessions included).
    fn record_events(&self, rec: &mut RecordingCtx) {
        match &self.inner {
            Inner::Fftu { plan, .. } => {
                rec.session_begin(analysis::EXEC_ARENA);
                extract::fftu_core(rec, plan);
                rec.session_end(analysis::EXEC_ARENA);
            }
            Inner::Slab(plan) => {
                rec.session_begin(analysis::SCRATCH_ARENA);
                extract::slab(rec, plan);
                rec.session_end(analysis::SCRATCH_ARENA);
            }
            Inner::Pencil(plan) => {
                rec.session_begin(analysis::SCRATCH_ARENA);
                extract::pencil(rec, plan);
                rec.session_end(analysis::SCRATCH_ARENA);
            }
            Inner::Heffte(plan) => {
                rec.session_begin(analysis::SCRATCH_ARENA);
                extract::heffte(rec, plan);
                rec.session_end(analysis::SCRATCH_ARENA);
            }
            Inner::Popovici(plan) => {
                rec.session_begin(analysis::SCRATCH_ARENA);
                extract::popovici(rec, plan);
                rec.session_end(analysis::SCRATCH_ARENA);
            }
            Inner::Real { core, .. } => {
                if self.t.strategy == DistStrategy::ZigZag {
                    let (plan, _) = Self::fftu_core(core);
                    rec.session_begin(analysis::EXEC_ARENA);
                    match self.t.kind {
                        Kind::R2C => {
                            extract::fftu_core(rec, plan);
                            extract::mirror_swap(rec, plan, "r2c-pairwise", false);
                            rec.begin_comp("r2c-untangle");
                        }
                        Kind::C2R => {
                            extract::mirror_swap(rec, plan, "c2r-pairwise", true);
                            rec.begin_comp("c2r-retangle");
                            extract::fftu_core(rec, plan);
                        }
                        Kind::Dct2 | Kind::Dst2 => {
                            extract::fftu_core(rec, plan);
                            extract::zigzag_convert(rec, plan);
                            rec.begin_comp("trig-combine");
                        }
                        Kind::Dct3 | Kind::Dst3 => {
                            rec.begin_comp("trig-phase");
                            extract::zigzag_convert(rec, plan);
                            extract::fftu_core(rec, plan);
                        }
                        Kind::C2C => unreachable!("c2c never wraps Inner::Real"),
                    }
                    rec.session_end(analysis::EXEC_ARENA);
                    if self.t.kind.is_trig() {
                        // The facade-level extraction sweep, charged
                        // after the SPMD run returns.
                        rec.begin_comp("trig-extract");
                    }
                    return;
                }
                // Gathered strategy: the complex core does all the
                // communication; the wrap pass is charged facade-level
                // after it (executed-ledger order).
                core.record_events(rec);
                match self.t.kind {
                    Kind::R2C => rec.begin_comp("r2c-untangle"),
                    Kind::C2R => rec.begin_comp("c2r-retangle"),
                    _ => rec.begin_comp("trig-wrap"),
                }
            }
            Inner::Auto { .. } => {
                unreachable!("analyze delegates to the chosen plan before recording")
            }
        }
    }

    /// The analytic cost ledger matching [`Self::record_events`]'s
    /// schedule superstep-for-superstep — the flow-conservation oracle.
    fn analytic_report(&self) -> Result<CostReport, FftError> {
        let shape = &self.t.shape;
        if self.t.kind == Kind::C2C {
            return match self.algo {
                Algorithm::Fftu => {
                    if let Inner::Fftu { plan, .. } = &self.inner {
                        if plan.is_ladder() {
                            let grid =
                                self.grid.as_deref().expect("fftu plans resolve a grid");
                            return Ok(costmodel::fftu_ladder_report(shape, grid));
                        }
                    }
                    Ok(costmodel::fftu_report(shape, self.p))
                }
                Algorithm::Slab { out } => {
                    costmodel::slab_report(shape, self.p, out == OutputDist::Same)
                }
                Algorithm::Pencil { r, out } => {
                    costmodel::pencil_report(shape, r, self.p, out == OutputDist::Same)
                }
                Algorithm::Heffte => costmodel::heffte_report(shape, self.p),
                Algorithm::Popovici => {
                    let grid = self.grid.as_deref().expect("popovici resolves a grid");
                    Ok(costmodel::popovici_report(shape, grid))
                }
                Algorithm::Auto => {
                    unreachable!("analyze delegates to the chosen plan before pricing")
                }
            };
        }
        if self.t.strategy == DistStrategy::ZigZag {
            let grid = self.grid.as_deref().expect("zig-zag plans resolve a grid");
            return Ok(match self.t.kind {
                Kind::R2C => costmodel::fftu_r2c_zigzag_report(shape, grid),
                Kind::C2R => costmodel::fftu_c2r_zigzag_report(shape, grid),
                Kind::Dct2 | Kind::Dst2 => {
                    costmodel::fftu_trig_zigzag_report(shape, grid, true)
                }
                Kind::Dct3 | Kind::Dst3 => {
                    costmodel::fftu_trig_zigzag_report(shape, grid, false)
                }
                Kind::C2C => unreachable!("handled above"),
            });
        }
        let core = self.real_inner().analytic_report()?;
        Ok(match self.t.kind {
            Kind::R2C | Kind::C2R => {
                costmodel::real_wrap_report(core, shape, self.p, self.t.kind)
            }
            _ => costmodel::trig_wrap_report(core, shape, self.p),
        })
    }

    fn run(&self, input: &[C64], batch: usize) -> Result<Execution, FftError> {
        if let Inner::Auto { chosen, table, chosen_idx } = &self.inner {
            // The winner is a complete plan for the same semantics
            // (kind, batch, normalization included): delegate wholesale
            // so scaling is applied exactly once. A session failure
            // fails over once to the next-cheapest candidate.
            return match chosen.run(input, batch) {
                Err(e) if Self::is_session_failure(&e) => {
                    self.auto_failover(*chosen_idx, table, e, |alt| alt.run(input, batch))
                }
                other => other,
            };
        }
        let n = self.t.total();
        if input.len() != batch * n {
            return Err(FftError::InputLength { expected: batch * n, got: input.len() });
        }
        let dir = self.t.direction;
        let inputs: Vec<&[C64]> = input.chunks(n).collect();
        let (mut outputs, report) = match &self.inner {
            Inner::Fftu { plan, arena } => fftu_execute_batch_arena(plan, arena, &inputs, dir)?,
            Inner::Slab(plan) => plan.try_execute_batch_global(&inputs, dir)?,
            Inner::Pencil(plan) => plan.try_execute_batch_global(&inputs, dir)?,
            Inner::Heffte(plan) => plan.try_execute_batch_global(&inputs, dir)?,
            Inner::Popovici(plan) => plan.try_execute_batch_global(&inputs, dir)?,
            Inner::Real { .. } => {
                unreachable!("real/trig kinds dispatch through run_r2c/run_c2r/run_trig")
            }
            Inner::Auto { .. } => unreachable!("delegated to the chosen plan above"),
        };
        let scale = self.t.normalization.scale(n);
        if scale != 1.0 {
            for out in &mut outputs {
                for v in out.iter_mut() {
                    *v = v.scale(scale);
                }
            }
        }
        let mut flat = Vec::with_capacity(input.len());
        for out in outputs {
            flat.extend(out);
        }
        Ok(Execution { output: flat, report })
    }

    /// R2C: pack adjacent last-axis pairs (local), run the complex core
    /// on the half shape (FFTU: still ONE all-to-all over half the
    /// volume), untangle by conjugate symmetry (local), normalize
    /// against the real total `N`.
    fn run_r2c(
        &self,
        input: &[f64],
        batch: usize,
        call: &'static str,
    ) -> Result<Execution, FftError> {
        self.ensure_kind(Kind::R2C, call)?;
        if let Inner::Auto { chosen, table, chosen_idx } = &self.inner {
            return match chosen.run_r2c(input, batch, call) {
                Err(e) if Self::is_session_failure(&e) => self.auto_failover(
                    *chosen_idx,
                    table,
                    e,
                    |alt| alt.run_r2c(input, batch, call),
                ),
                other => other,
            };
        }
        let n = self.t.total();
        if input.len() != batch * n {
            return Err(FftError::InputLength { expected: batch * n, got: input.len() });
        }
        // Row-major + even last axis: items stay pair-aligned, so the
        // whole batch packs in one pass.
        let packed = pack_pairs(input);
        let nh = n / 2;
        let nspec = self.t.spectrum_total();
        let scale = self.t.normalization.scale(n);
        if self.t.strategy == DistStrategy::ZigZag {
            // Rank-local untangle: one pairwise mirror exchange after
            // the core, untangle in-SPMD (charged there), assembled
            // spectra back. Bit-identical to the gathered path below.
            let (plan, arena) = Self::fftu_core(self.real_inner());
            let items: Vec<&[C64]> = packed.chunks(nh).collect();
            let (spectra, report) = fftu_execute_r2c_pairwise_batch_arena(
                plan,
                arena,
                &self.t.shape,
                &items,
                self.r2c_twiddles(),
            )?;
            let mut output = Vec::with_capacity(batch * nspec);
            for mut spec in spectra {
                if scale != 1.0 {
                    for v in spec.iter_mut() {
                        *v = v.scale(scale);
                    }
                }
                output.extend(spec);
            }
            return Ok(Execution { output, report });
        }
        let half = self.real_inner().run(&packed, batch)?;
        let mut output = Vec::with_capacity(batch * nspec);
        for item in half.output.chunks(nh) {
            let mut spec = untangle_half_spectrum(item, &self.t.shape);
            if scale != 1.0 {
                for v in spec.iter_mut() {
                    *v = v.scale(scale);
                }
            }
            output.extend(spec);
        }
        let mut report = half.report;
        report.push_comp("r2c-untangle", batch as f64 * wrap_flops(&self.t.shape) / self.p as f64);
        Ok(Execution { output, report })
    }

    /// C2R: retangle the Hermitian half-spectrum (local), run the inverse
    /// complex core on the half shape, unpack pairs. The raw (`None`)
    /// result is `N x` — the same unnormalized convention as C2C, so
    /// [`super::Normalization::ByN`] gives the exact inverse of an
    /// unnormalized R2C.
    fn run_c2r(
        &self,
        input: &[C64],
        batch: usize,
        call: &'static str,
    ) -> Result<RealExecution, FftError> {
        self.ensure_kind(Kind::C2R, call)?;
        if let Inner::Auto { chosen, table, chosen_idx } = &self.inner {
            return match chosen.run_c2r(input, batch, call) {
                Err(e) if Self::is_session_failure(&e) => self.auto_failover(
                    *chosen_idx,
                    table,
                    e,
                    |alt| alt.run_c2r(input, batch, call),
                ),
                other => other,
            };
        }
        let n = self.t.total();
        let nh = n / 2;
        let nspec = self.t.spectrum_total();
        if input.len() != batch * nspec {
            return Err(FftError::InputLength { expected: batch * nspec, got: input.len() });
        }
        // The unnormalized inverse over N/2 points yields (N/2) z;
        // doubling makes the raw c2r the true N-scaled adjoint.
        let scale = 2.0 * self.t.normalization.scale(n);
        if self.t.strategy == DistStrategy::ZigZag {
            // Rank-local retangle: spectrum shares swap with the
            // conjugate partner before the core; retangle charged
            // in-SPMD. Bit-identical to the gathered path below.
            let (plan, arena) = Self::fftu_core(self.real_inner());
            let items: Vec<&[C64]> = input.chunks(nspec).collect();
            let (zs, report) = fftu_execute_c2r_pairwise_batch_arena(
                plan,
                arena,
                &self.t.shape,
                &items,
                self.r2c_twiddles(),
            )?;
            let mut output = Vec::with_capacity(batch * n);
            for z in zs {
                output.extend(unpack_pairs(&z, scale));
            }
            return Ok(RealExecution { output, report });
        }
        let mut packed = Vec::with_capacity(batch * nh);
        for item in input.chunks(nspec) {
            packed.extend(retangle_half_spectrum(item, &self.t.shape));
        }
        let half = self.real_inner().run(&packed, batch)?;
        let output = unpack_pairs(&half.output, scale);
        let mut report = half.report;
        report.push_comp("c2r-retangle", batch as f64 * wrap_flops(&self.t.shape) / self.p as f64);
        Ok(RealExecution { output, report })
    }

    /// Trig kinds (DCT-II/III, DST-II/III): local per-axis Makhoul
    /// permutations and quarter-wave phase passes around the complex
    /// core on the full shape. Through FFTU the permutation is composed
    /// into the cyclic scatter (type 2) / gather (type 3) — no permuted
    /// global array is materialized and the single all-to-all survives;
    /// every other algorithm wraps its ordinary complex batch path. The
    /// phase passes run facade-level and are charged to the ledger as
    /// one computation superstep (`trig-wrap`), exactly mirroring the
    /// analytic model's `trig_wrap_flops` — the two match bit-for-bit.
    fn run_trig(
        &self,
        input: &[f64],
        batch: usize,
        call: &'static str,
    ) -> Result<RealExecution, FftError> {
        if !self.t.kind.is_trig() {
            return Err(FftError::KindMismatch {
                kind: self.t.kind.name(),
                call,
                expected: "dct2|dct3|dst2|dst3",
            });
        }
        if let Inner::Auto { chosen, table, chosen_idx } = &self.inner {
            return match chosen.run_trig(input, batch, call) {
                Err(e) if Self::is_session_failure(&e) => self.auto_failover(
                    *chosen_idx,
                    table,
                    e,
                    |alt| alt.run_trig(input, batch, call),
                ),
                other => other,
            };
        }
        let n = self.t.total();
        if input.len() != batch * n {
            return Err(FftError::InputLength { expected: batch * n, got: input.len() });
        }
        let shape = &self.t.shape;
        let scale = self.t.normalization.scale(n);
        let inner = self.real_inner();
        let tables = self.trig_tables();
        let items: Vec<&[f64]> = input.chunks(n).collect();
        if self.t.strategy == DistStrategy::ZigZag {
            // Rank-local combine/phase passes via the zig-zag cyclic
            // distribution: one pairwise exchange per shared axis
            // converts between the core's cyclic data and the zig-zag
            // layout where every mirror pair is co-located; the
            // extraction sweep stays driver-level and is charged as
            // `trig-extract` (combine flops are charged in-SPMD).
            // Bit-identical to the gathered path below.
            let (plan, arena) = Self::fftu_core(inner);
            let dst = matches!(self.t.kind, Kind::Dst2 | Kind::Dst3);
            let (outs, mut report) = if matches!(self.t.kind, Kind::Dct2 | Kind::Dst2) {
                fftu_execute_trig2_zigzag_batch_arena(plan, arena, &items, dst, tables, scale)?
            } else {
                fftu_execute_trig3_zigzag_batch_arena(plan, arena, &items, dst, tables, scale)?
            };
            let output: Vec<f64> = outs.into_iter().flatten().collect();
            report.push_comp(
                "trig-extract",
                batch as f64 * trig_extract_flops(shape) / self.p as f64,
            );
            return Ok(RealExecution { output, report });
        }
        let (output, mut report) = match self.t.kind {
            Kind::Dct2 | Kind::Dst2 => {
                let dst = self.t.kind == Kind::Dst2;
                // Forward core, then the combine passes on each item.
                let (core_items, report) = match &inner.inner {
                    Inner::Fftu { plan, arena } => {
                        fftu_execute_trig2_batch_arena(plan, arena, &items, dst)?
                    }
                    _ => {
                        let pre: Vec<C64> = items
                            .iter()
                            .flat_map(|item| trig2_pre(item, shape, dst))
                            .collect();
                        let exec = inner.run(&pre, batch)?;
                        (exec.output.chunks(n).map(<[C64]>::to_vec).collect(), exec.report)
                    }
                };
                let mut output = Vec::with_capacity(batch * n);
                for mut v in core_items {
                    output.extend(trig2_post(&mut v, shape, tables, dst, scale));
                }
                (output, report)
            }
            Kind::Dct3 | Kind::Dst3 => {
                let dst = self.t.kind == Kind::Dst3;
                let pre_items: Vec<Vec<C64>> =
                    items.iter().map(|item| trig3_pre(item, shape, tables, dst)).collect();
                match &inner.inner {
                    Inner::Fftu { plan, arena } => {
                        let refs: Vec<&[C64]> =
                            pre_items.iter().map(Vec::as_slice).collect();
                        let (outs, report) =
                            fftu_execute_trig3_batch_arena(plan, arena, &refs, dst, scale)?;
                        (outs.into_iter().flatten().collect(), report)
                    }
                    _ => {
                        let pre: Vec<C64> = pre_items.into_iter().flatten().collect();
                        let exec = inner.run(&pre, batch)?;
                        let mut output = Vec::with_capacity(batch * n);
                        for item in exec.output.chunks(n) {
                            output.extend(trig3_extract(item, shape, dst, scale));
                        }
                        (output, exec.report)
                    }
                }
            }
            _ => unreachable!("guarded by is_trig above"),
        };
        report.push_comp("trig-wrap", batch as f64 * trig_wrap_flops(shape) / self.p as f64);
        Ok(RealExecution { output, report })
    }
}

impl DistFft for PlannedFft {
    fn algorithm(&self) -> Algorithm {
        PlannedFft::algorithm(self)
    }

    fn transform(&self) -> &Transform {
        PlannedFft::transform(self)
    }

    fn procs(&self) -> usize {
        PlannedFft::procs(self)
    }

    fn grid(&self) -> Option<&[usize]> {
        PlannedFft::grid(self)
    }

    fn execute(&self, io: BatchIo<'_>) -> Result<BatchOut, FftError> {
        self.execute_io(io, self.t.batch, "execute")
    }

    fn execute_one(&self, io: BatchIo<'_>) -> Result<BatchOut, FftError> {
        self.execute_io(io, 1, "execute_one")
    }

    #[allow(deprecated)]
    fn execute_batch(&self, input: &[C64]) -> Result<Execution, FftError> {
        PlannedFft::execute_batch(self, input)
    }

    #[allow(deprecated)]
    fn execute_r2c(&self, input: &[f64]) -> Result<Execution, FftError> {
        PlannedFft::execute_r2c(self, input)
    }

    #[allow(deprecated)]
    fn execute_r2c_batch(&self, input: &[f64]) -> Result<Execution, FftError> {
        PlannedFft::execute_r2c_batch(self, input)
    }

    #[allow(deprecated)]
    fn execute_c2r(&self, input: &[C64]) -> Result<RealExecution, FftError> {
        PlannedFft::execute_c2r(self, input)
    }

    #[allow(deprecated)]
    fn execute_c2r_batch(&self, input: &[C64]) -> Result<RealExecution, FftError> {
        PlannedFft::execute_c2r_batch(self, input)
    }

    #[allow(deprecated)]
    fn execute_trig(&self, input: &[f64]) -> Result<RealExecution, FftError> {
        PlannedFft::execute_trig(self, input)
    }

    #[allow(deprecated)]
    fn execute_trig_batch(&self, input: &[f64]) -> Result<RealExecution, FftError> {
        PlannedFft::execute_trig_batch(self, input)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::fft::{dft_nd, rel_l2_error, Direction};
    use crate::testing::Rng;

    fn rand(n: usize, seed: u64) -> Vec<C64> {
        let mut rng = Rng::new(seed);
        (0..n).map(|_| C64::new(rng.f64_signed(), rng.f64_signed())).collect()
    }

    #[test]
    fn plan_resolves_auto_grid_for_cyclic_algorithms() {
        let t = Transform::new(&[16, 16]).procs(4);
        let p = plan(Algorithm::Fftu, &t).unwrap();
        assert_eq!(p.grid().unwrap().iter().product::<usize>(), 4);
        assert_eq!(p.procs(), 4);
        let p = plan(Algorithm::Popovici, &t).unwrap();
        assert_eq!(p.grid().unwrap().iter().product::<usize>(), 4);
    }

    #[test]
    fn execute_through_trait_object() {
        let t = Transform::new(&[8, 8]).procs(2);
        let planned: Arc<dyn DistFft> = plan(Algorithm::Fftu, &t).unwrap();
        let x = rand(64, 0xAB);
        let want = dft_nd(&x, &[8, 8], Direction::Forward);
        // Through `dyn DistFft` the typed buffer is wrapped explicitly.
        let got = planned.execute(BatchIo::Complex(&x)).unwrap().complex();
        assert!(rel_l2_error(&got.output, &want) < 1e-9);
        assert_eq!(got.report.comm_supersteps(), 1);
    }

    #[test]
    fn execute_rejects_wrong_length_with_typed_error() {
        let t = Transform::new(&[8, 8]).procs(2);
        let planned = plan(Algorithm::Fftu, &t).unwrap();
        assert_eq!(
            planned.execute(&[C64::ZERO; 10]).unwrap_err(),
            FftError::InputLength { expected: 64, got: 10 }
        );
        let batched = plan(Algorithm::Fftu, &Transform::new(&[8, 8]).procs(2).batch(3)).unwrap();
        // `execute` runs the descriptor's whole batch: 3 items expected.
        assert_eq!(
            batched.execute(&[C64::ZERO; 64]).unwrap_err(),
            FftError::InputLength { expected: 192, got: 64 }
        );
        // `execute_one` runs one item regardless of the descriptor batch.
        assert_eq!(
            batched.execute_one(&[C64::ZERO; 10]).unwrap_err(),
            FftError::InputLength { expected: 64, got: 10 }
        );
    }

    #[test]
    fn r2c_plan_resolves_grid_on_the_half_shape() {
        let t = Transform::new(&[16, 16]).procs(4).r2c();
        let planned = plan(Algorithm::Fftu, &t).unwrap();
        // Grid lives on the packed half shape [16, 8].
        let grid = planned.grid().unwrap();
        assert_eq!(grid.iter().product::<usize>(), 4);
        assert_eq!(planned.procs(), 4);
        for (l, &q) in grid.iter().enumerate() {
            let half = [16usize, 8];
            assert_eq!(half[l] % (q * q), 0, "grid {grid:?}");
        }
    }

    #[test]
    fn r2c_matches_sequential_rfftn_and_keeps_one_alltoall() {
        use crate::fft::realnd::rfftn;
        let shape = [8usize, 16];
        let n = 128;
        let mut rng = Rng::new(0xAC);
        let x: Vec<f64> = (0..n).map(|_| rng.f64_signed()).collect();
        let want = rfftn(&x, &shape);
        let planned = plan(Algorithm::Fftu, &Transform::new(&shape).procs(4).r2c()).unwrap();
        let got = planned.execute(&x).unwrap().complex();
        assert_eq!(got.output.len(), 8 * 9);
        assert!(rel_l2_error(&got.output, &want) < 1e-10);
        assert_eq!(got.report.comm_supersteps(), 1);
    }

    #[test]
    fn c2r_with_by_n_inverts_unnormalized_r2c() {
        use crate::api::Normalization;
        let shape = [4usize, 6, 8];
        let n = 192;
        let mut rng = Rng::new(0xAD);
        let x: Vec<f64> = (0..n).map(|_| rng.f64_signed()).collect();
        let fwd = plan(Algorithm::Fftu, &Transform::new(&shape).procs(2).r2c()).unwrap();
        let spec = fwd.execute(&x).unwrap().complex();
        let inv = plan(
            Algorithm::Fftu,
            &Transform::new(&shape).procs(2).c2r().normalization(Normalization::ByN),
        )
        .unwrap();
        let back = inv.execute(&spec.output).unwrap().real();
        let err = x.iter().zip(&back.output).map(|(a, b)| (a - b).abs()).fold(0.0, f64::max);
        assert!(err < 1e-10, "roundtrip err {err}");
    }

    #[test]
    fn kind_mismatch_is_a_typed_error() {
        // An R2C plan wants real input: a complex buffer is rejected
        // with the kinds that COULD take it.
        let r2c = plan(Algorithm::Fftu, &Transform::new(&[8, 8]).procs(2).r2c()).unwrap();
        assert_eq!(
            r2c.execute(&[C64::ZERO; 64]).unwrap_err(),
            FftError::KindMismatch { kind: "r2c", call: "execute", expected: "c2c|c2r" }
        );
        // A C2C plan wants complex input: a real buffer is rejected.
        let c2c = plan(Algorithm::Fftu, &Transform::new(&[8, 8]).procs(2)).unwrap();
        assert_eq!(
            c2c.execute(&[0.0; 64]).unwrap_err(),
            FftError::KindMismatch {
                kind: "c2c",
                call: "execute",
                expected: "r2c|dct2|dct3|dst2|dst3"
            }
        );
        assert_eq!(
            c2c.execute_one(&[0.0; 64]).unwrap_err(),
            FftError::KindMismatch {
                kind: "c2c",
                call: "execute_one",
                expected: "r2c|dct2|dct3|dst2|dst3"
            }
        );
        // Real-kind input lengths are checked against the real/spectrum
        // totals.
        assert_eq!(
            r2c.execute(&[0.0; 10]).unwrap_err(),
            FftError::InputLength { expected: 64, got: 10 }
        );
        let c2r = plan(Algorithm::Fftu, &Transform::new(&[8, 8]).procs(2).c2r()).unwrap();
        assert_eq!(
            c2r.execute(&[C64::ZERO; 10]).unwrap_err(),
            FftError::InputLength { expected: 8 * 5, got: 10 }
        );
    }

    /// The pre-0.3 kind-specific entry points still work as thin
    /// delegates onto the unified front door, with their original typed
    /// errors intact.
    #[test]
    #[allow(deprecated)]
    fn deprecated_entry_points_still_delegate() {
        let c2c = plan(Algorithm::Fftu, &Transform::new(&[8, 8]).procs(2)).unwrap();
        assert_eq!(
            c2c.execute_r2c(&[0.0; 64]).unwrap_err(),
            FftError::KindMismatch { kind: "c2c", call: "execute_r2c", expected: "r2c" }
        );
        assert_eq!(
            c2c.execute_c2r(&[C64::ZERO; 64]).unwrap_err(),
            FftError::KindMismatch { kind: "c2c", call: "execute_c2r", expected: "c2r" }
        );
        assert_eq!(
            c2c.execute_trig(&[0.0; 64]).unwrap_err(),
            FftError::KindMismatch {
                kind: "c2c",
                call: "execute_trig",
                expected: "dct2|dct3|dst2|dst3"
            }
        );
        // And on matching kinds they return the same bits as the
        // unified surface.
        let x = rand(64, 0xBEEF);
        let via_new = c2c.execute(&x).unwrap().complex();
        let via_old = c2c.execute_batch(&x).unwrap();
        assert_eq!(via_new.output, via_old.output);
        let r2c = plan(Algorithm::Fftu, &Transform::new(&[8, 8]).procs(2).r2c()).unwrap();
        let xr: Vec<f64> = x.iter().map(|v| v.re).collect();
        assert_eq!(
            r2c.execute_r2c(&xr).unwrap().output,
            r2c.execute(&xr).unwrap().complex().output
        );
    }

    #[test]
    fn trig_plans_execute_all_kinds_and_keep_one_alltoall() {
        use crate::fft::trignd::{dctn2, dctn3, dstn2, dstn3};
        let shape = [8usize, 12];
        let n = 96;
        let mut rng = Rng::new(0x7A11);
        let x: Vec<f64> = (0..n).map(|_| rng.f64_signed()).collect();
        let cases: [(Kind, Vec<f64>); 4] = [
            (Kind::Dct2, dctn2(&x, &shape)),
            (Kind::Dct3, dctn3(&x, &shape)),
            (Kind::Dst2, dstn2(&x, &shape)),
            (Kind::Dst3, dstn3(&x, &shape)),
        ];
        for (kind, want) in cases {
            let planned =
                plan(Algorithm::Fftu, &Transform::new(&shape).procs(4).kind(kind)).unwrap();
            let got = planned.execute(&x).unwrap().real();
            let err =
                got.output.iter().zip(&want).map(|(a, b)| (a - b).abs()).fold(0.0, f64::max);
            assert!(err < 1e-9 * n as f64, "{kind:?}: err {err}");
            assert_eq!(got.report.comm_supersteps(), 1, "{kind:?}");
            // The same descriptor through a transposing baseline agrees.
            let slab =
                plan(Algorithm::slab(), &Transform::new(&shape).procs(2).kind(kind)).unwrap();
            let got = slab.execute(&x).unwrap().real();
            let err =
                got.output.iter().zip(&want).map(|(a, b)| (a - b).abs()).fold(0.0, f64::max);
            assert!(err < 1e-9 * n as f64, "slab {kind:?}: err {err}");
        }
    }

    #[test]
    fn trig_batch_and_normalization() {
        use crate::api::Normalization;
        let shape = [4usize, 6];
        let n = 24;
        let mut rng = Rng::new(0xDD);
        let x: Vec<f64> = (0..2 * n).map(|_| rng.f64_signed()).collect();
        let fwd = plan(
            Algorithm::Fftu,
            &Transform::new(&shape).procs(2).dct2().batch(2),
        )
        .unwrap();
        let coeff = fwd.execute(&x).unwrap().real();
        assert_eq!(coeff.report.comm_supersteps(), 2); // one all-to-all per item
        // ByN on the inverse leaves the textbook 2^d residual:
        // dct3(dct2(x)) = prod(2 n_l) x = 2^d N x.
        let inv = plan(
            Algorithm::Fftu,
            &Transform::new(&shape)
                .procs(2)
                .dct3()
                .normalization(Normalization::ByN)
                .batch(2),
        )
        .unwrap();
        let back = inv.execute(&coeff.output).unwrap().real();
        let two_d = 4.0; // 2^d for d = 2
        let err = x
            .iter()
            .zip(&back.output)
            .map(|(a, b)| (b / two_d - a).abs())
            .fold(0.0, f64::max);
        assert!(err < 1e-10, "batch roundtrip err {err}");
    }

    #[test]
    fn trig_kind_mismatch_and_length_are_typed_errors() {
        let dct = plan(Algorithm::Fftu, &Transform::new(&[8, 8]).procs(2).dct2()).unwrap();
        // A trig plan wants real input: complex buffers are rejected.
        assert_eq!(
            dct.execute(&[C64::ZERO; 64]).unwrap_err(),
            FftError::KindMismatch { kind: "dct2", call: "execute", expected: "c2c|c2r" }
        );
        assert_eq!(
            dct.execute(&[0.0; 10]).unwrap_err(),
            FftError::InputLength { expected: 64, got: 10 }
        );
    }

    #[test]
    fn zigzag_trig_is_bit_identical_to_gathered_oracle() {
        use crate::api::DistStrategy;
        use crate::bsp::SuperstepKind;
        let mut rng = Rng::new(0x5A5A);
        for (shape, grid) in [
            (vec![18usize, 16], vec![3usize, 4]),
            (vec![36], vec![3]),
            (vec![18, 5, 8], vec![3, 1, 2]),
            (vec![16, 16], vec![2, 2]), // p_l <= 2: zero pairwise exchanges
            (vec![4, 16], vec![2, 4]),  // Q = n/(2p) = 1 on axis 0
        ] {
            let n: usize = shape.iter().product();
            let x: Vec<f64> = (0..n).map(|_| rng.f64_signed()).collect();
            for kind in [Kind::Dct2, Kind::Dct3, Kind::Dst2, Kind::Dst3] {
                let gathered =
                    plan(Algorithm::Fftu, &Transform::new(&shape).grid(&grid).kind(kind))
                        .unwrap();
                let zz = plan(
                    Algorithm::Fftu,
                    &Transform::new(&shape).grid(&grid).kind(kind).zigzag(),
                )
                .unwrap();
                assert_eq!(zz.transform().strategy, DistStrategy::ZigZag);
                let want = gathered.execute(&x).unwrap().real();
                let got = zz.execute(&x).unwrap().real();
                // Bit-exact: the rank-local passes run the same
                // floating-point expressions on the same values.
                assert_eq!(got.output, want.output, "{kind:?} {shape:?} {grid:?}");
                // Exactly ONE all-to-all; everything else pairwise/local.
                let alltoalls = got
                    .report
                    .supersteps
                    .iter()
                    .filter(|s| s.label == "fftu-alltoall")
                    .count();
                assert_eq!(alltoalls, 1, "{kind:?} {shape:?}");
                for s in &got.report.supersteps {
                    if s.kind == SuperstepKind::Communication && s.label != "fftu-alltoall" {
                        assert_eq!(s.label, "zigzag-exchange", "{kind:?} {shape:?}");
                        assert!(s.h_max <= n / zz.procs() / 2, "{kind:?}: pairwise h too big");
                    }
                }
            }
        }
    }

    #[test]
    fn zigzag_r2c_c2r_are_bit_identical_to_gathered_oracles() {
        let mut rng = Rng::new(0x5A5B);
        for (shape, grid) in [
            (vec![8usize, 36], vec![2usize, 3]),
            (vec![18, 8], vec![3, 2]),
            (vec![36, 8], vec![6, 2]),
            (vec![16], vec![2]),
            (vec![4, 6, 8], vec![2, 1, 2]),
        ] {
            let n: usize = shape.iter().product();
            let x: Vec<f64> = (0..n).map(|_| rng.f64_signed()).collect();
            let gathered =
                plan(Algorithm::Fftu, &Transform::new(&shape).grid(&grid).r2c()).unwrap();
            let zz = plan(Algorithm::Fftu, &Transform::new(&shape).grid(&grid).r2c().zigzag())
                .unwrap();
            let want = gathered.execute(&x).unwrap().complex();
            let got = zz.execute(&x).unwrap().complex();
            assert_eq!(got.output, want.output, "r2c {shape:?} {grid:?}");
            assert_eq!(
                got.report.supersteps.iter().filter(|s| s.label == "fftu-alltoall").count(),
                1,
                "r2c {shape:?}"
            );
            // C2R: the adjoint, from the spectrum back to the signal.
            let gathered_inv =
                plan(Algorithm::Fftu, &Transform::new(&shape).grid(&grid).c2r()).unwrap();
            let zz_inv =
                plan(Algorithm::Fftu, &Transform::new(&shape).grid(&grid).c2r().zigzag())
                    .unwrap();
            let want_back = gathered_inv.execute(&want.output).unwrap().real();
            let got_back = zz_inv.execute(&want.output).unwrap().real();
            assert_eq!(got_back.output, want_back.output, "c2r {shape:?} {grid:?}");
        }
    }

    #[test]
    fn zigzag_plan_time_validation() {
        // FFTU-only.
        assert!(matches!(
            plan(Algorithm::slab(), &Transform::new(&[12, 12]).procs(2).dct2().zigzag()),
            Err(FftError::Unsupported { .. })
        ));
        // c2c has no wrapper passes to distribute.
        assert!(plan(Algorithm::Fftu, &Transform::new(&[12, 12]).procs(2).zigzag()).is_err());
        // Trig needs 2 p_l | n_l on shared axes: 9 = 3^2 passes the core
        // rule p^2 | n but not the zig-zag folding.
        assert!(matches!(
            plan(Algorithm::Fftu, &Transform::new(&[9, 8]).grid(&[3, 2]).dct2().zigzag()),
            Err(FftError::AxisConstraint { axis: 0, n: 9, p: 3, requires: "2 p_l | n_l (zig-zag)" })
        ));
        // The same shape is fine under the gathered strategy...
        assert!(plan(Algorithm::Fftu, &Transform::new(&[9, 8]).grid(&[3, 2]).dct2()).is_ok());
        // ...and r2c has no such constraint (the mirror exchange is a
        // full-copy swap, no folding): half shape [9, 4] with grid [3, 2].
        assert!(
            plan(Algorithm::Fftu, &Transform::new(&[9, 8]).grid(&[3, 2]).r2c().zigzag()).is_ok()
        );
    }

    #[test]
    fn documented_comm_superstep_formulas() {
        assert_eq!(Algorithm::Fftu.comm_supersteps(3), 1);
        assert_eq!(Algorithm::slab().comm_supersteps(3), 2);
        assert_eq!(Algorithm::Slab { out: OutputDist::Different }.comm_supersteps(3), 1);
        assert_eq!(Algorithm::pencil(2).comm_supersteps(3), 3);
        assert_eq!(Algorithm::Pencil { r: 2, out: OutputDist::Different }.comm_supersteps(5), 1);
        assert_eq!(Algorithm::Heffte.comm_supersteps(3), 4);
        assert_eq!(Algorithm::Popovici.comm_supersteps(3), 3);
    }

    #[test]
    fn parse_round_trips_names() {
        for name in ["fftu", "slab", "pencil", "heffte", "popovici", "auto"] {
            assert_eq!(Algorithm::parse(name).unwrap().name(), name);
        }
        assert!(Algorithm::parse("nope").is_none());
    }

    #[test]
    fn auto_plans_delegate_execution_to_the_chosen_candidate() {
        let t = Transform::new(&[16, 16]).procs(4);
        let auto = plan(Algorithm::Auto, &t).unwrap();
        assert_eq!(auto.algorithm(), Algorithm::Auto);
        let chosen = auto.chosen().expect("auto plans expose their winner");
        assert_ne!(chosen.algorithm(), Algorithm::Auto);
        let table = auto.planner_table().expect("auto plans keep the scored table");
        assert!(!table.is_empty());
        // The table is sorted cheapest-predicted first.
        for pair in table.windows(2) {
            assert!(pair[0].predicted_s <= pair[1].predicted_s);
        }
        // Execution delegates to the winner and matches the oracle.
        let x = rand(256, 0xA7);
        let want = dft_nd(&x, &[16, 16], Direction::Forward);
        let got = auto.execute(&x).unwrap().complex();
        assert!(rel_l2_error(&got.output, &want) < 1e-9);
        // Explicit plans never expose a winner or a table.
        let explicit = plan(Algorithm::Fftu, &t).unwrap();
        assert!(explicit.chosen().is_none());
        assert!(explicit.planner_table().is_none());
    }
}
