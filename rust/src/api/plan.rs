//! Plan-time validation and the unified execute path: the [`Algorithm`]
//! enum, the [`DistFft`] trait, and [`plan`], which turns a
//! ([`Algorithm`], [`Transform`]) pair into a reusable [`PlannedFft`].
//!
//! Planning does all the expensive, fallible work once — grid
//! resolution, divisibility checks, distribution schedules, compiled
//! redistributions, local FFT plans — so execution is infallible apart
//! from input-length checks and can be repeated (and batched) with no
//! replanning. [`super::PlanCache`] builds on this split.

use std::sync::Arc;

use crate::baselines::{HefftePlan, OutputDist, PencilPlan, PopoviciPlan, SlabPlan};
use crate::bsp::CostReport;
use crate::fft::{C64, Planner};
use crate::fftu::{choose_grid, fftu_execute_batch, fftu_pmax, FftuPlan};

use super::error::FftError;
use super::transform::{Grid, Transform};

/// Which distributed-FFT algorithm executes a [`Transform`].
///
/// All five run on the same BSP machine and sequential FFT substrate, so
/// choosing between them changes *communication structure only* — the
/// paper's subject.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum Algorithm {
    /// The paper's contribution: cyclic-to-cyclic, ONE all-to-all.
    Fftu,
    /// Parallel-FFTW slab decomposition (§1.2).
    Slab { out: OutputDist },
    /// PFFT r-dimensional block decomposition (§1.2).
    Pencil { r: usize, out: OutputDist },
    /// heFFTe brick-to-brick pipeline (§1.2).
    Heffte,
    /// Popovici et al. cyclic d-step (§1.2).
    Popovici,
}

impl Algorithm {
    /// Slab with the paper's default same-distribution output.
    pub fn slab() -> Self {
        Algorithm::Slab { out: OutputDist::Same }
    }

    /// Pencil with decomposition rank `r` and same-distribution output.
    pub fn pencil(r: usize) -> Self {
        Algorithm::Pencil { r, out: OutputDist::Same }
    }

    pub fn name(self) -> &'static str {
        match self {
            Algorithm::Fftu => "fftu",
            Algorithm::Slab { .. } => "slab",
            Algorithm::Pencil { .. } => "pencil",
            Algorithm::Heffte => "heffte",
            Algorithm::Popovici => "popovici",
        }
    }

    /// Parse a CLI-style name; `pencil` defaults to `r = 2` capped at
    /// `d - 1` when the shape rank is known to the caller.
    pub fn parse(s: &str) -> Option<Algorithm> {
        match s {
            "fftu" => Some(Algorithm::Fftu),
            "slab" => Some(Algorithm::slab()),
            "pencil" => Some(Algorithm::pencil(2)),
            "heffte" => Some(Algorithm::Heffte),
            "popovici" => Some(Algorithm::Popovici),
            _ => None,
        }
    }

    /// Documented communication-superstep count for a d-dimensional
    /// transform — the paper's headline comparison (§1.2, Eq. 2.12).
    pub fn comm_supersteps(self, d: usize) -> usize {
        match self {
            Algorithm::Fftu => 1,
            Algorithm::Slab { out } => 1 + usize::from(out == OutputDist::Same),
            Algorithm::Pencil { r, out } => {
                // ceil(r / (d-r)) for a valid 1 <= r < d; clamp the span
                // so an invalid r (which `plan` rejects) cannot divide by
                // zero here.
                let span = d.saturating_sub(r).max(1);
                let stages = (r + span - 1) / span;
                stages + usize::from(out == OutputDist::Same)
            }
            Algorithm::Heffte => d + 1,
            Algorithm::Popovici => d,
        }
    }
}

/// Result of executing a planned transform: the output array(s), back to
/// back for a batch, plus the exact BSP cost ledger of the run.
#[derive(Debug)]
pub struct Execution {
    pub output: Vec<C64>,
    pub report: CostReport,
}

/// The unified plan/execute interface every algorithm implements (via
/// [`PlannedFft`]). Plans are immutable and `Send + Sync`: share one
/// behind an `Arc` and execute from as many threads as you like.
pub trait DistFft: Send + Sync {
    /// The algorithm this plan executes.
    fn algorithm(&self) -> Algorithm;
    /// The descriptor this plan was built from.
    fn transform(&self) -> &Transform;
    /// Total processors the plan runs on.
    fn procs(&self) -> usize;
    /// The resolved per-axis cyclic grid (FFTU/Popovici), if any.
    fn grid(&self) -> Option<&[usize]>;
    /// Execute ONE transform (`shape.product()` elements, regardless of
    /// the descriptor's batch count).
    fn execute(&self, input: &[C64]) -> Result<Execution, FftError>;
    /// Execute the descriptor's `batch` transforms from one contiguous
    /// buffer, amortizing per-rank state across the batch.
    fn execute_batch(&self, input: &[C64]) -> Result<Execution, FftError>;
}

enum Inner {
    Fftu(Arc<FftuPlan>),
    Slab(SlabPlan),
    Pencil(PencilPlan),
    Heffte(HefftePlan),
    Popovici(PopoviciPlan),
}

/// A validated, reusable plan binding a [`Transform`] to an
/// [`Algorithm`]. Built by [`plan`] (or [`Transform::plan`] /
/// [`super::PlanCache::plan`]); executing it never replans.
pub struct PlannedFft {
    algo: Algorithm,
    t: Transform,
    grid: Option<Vec<usize>>,
    p: usize,
    inner: Inner,
}

/// Resolve the per-axis cyclic grid for the cyclic-family algorithms.
fn resolve_cyclic_grid(t: &Transform) -> Result<Vec<usize>, FftError> {
    match &t.grid {
        Grid::Explicit(g) => Ok(g.clone()),
        Grid::Auto { p } => choose_grid(&t.shape, *p)
            .ok_or(FftError::NoValidGrid { p: *p, pmax: fftu_pmax(&t.shape) }),
    }
}

/// Validate `t` and build a reusable plan for `algo`.
pub fn plan(algo: Algorithm, t: &Transform) -> Result<Arc<PlannedFft>, FftError> {
    t.validate()?;
    let p = t.grid.procs();
    let (inner, grid, p) = match algo {
        Algorithm::Fftu => {
            let grid = resolve_cyclic_grid(t)?;
            let planner = Planner::new();
            let plan = Arc::new(FftuPlan::new(&t.shape, &grid, &planner)?);
            let p = plan.num_procs();
            (Inner::Fftu(plan), Some(grid), p)
        }
        Algorithm::Slab { out } => (Inner::Slab(SlabPlan::new(&t.shape, p, out)?), None, p),
        Algorithm::Pencil { r, out } => {
            (Inner::Pencil(PencilPlan::new(&t.shape, r, p, out)?), None, p)
        }
        Algorithm::Heffte => (Inner::Heffte(HefftePlan::new(&t.shape, p)?), None, p),
        Algorithm::Popovici => {
            let grid = resolve_cyclic_grid(t)?;
            let plan = PopoviciPlan::new(&t.shape, &grid)?;
            let p = plan.num_procs();
            (Inner::Popovici(plan), Some(grid), p)
        }
    };
    Ok(Arc::new(PlannedFft { algo, t: t.clone(), grid, p, inner }))
}

impl PlannedFft {
    pub fn algorithm(&self) -> Algorithm {
        self.algo
    }

    pub fn transform(&self) -> &Transform {
        &self.t
    }

    pub fn procs(&self) -> usize {
        self.p
    }

    pub fn grid(&self) -> Option<&[usize]> {
        self.grid.as_deref()
    }

    /// Execute ONE transform; see [`DistFft::execute`].
    pub fn execute(&self, input: &[C64]) -> Result<Execution, FftError> {
        self.run(input, 1)
    }

    /// Execute the descriptor's batch; see [`DistFft::execute_batch`].
    pub fn execute_batch(&self, input: &[C64]) -> Result<Execution, FftError> {
        self.run(input, self.t.batch)
    }

    fn run(&self, input: &[C64], batch: usize) -> Result<Execution, FftError> {
        let n = self.t.total();
        if input.len() != batch * n {
            return Err(FftError::InputLength { expected: batch * n, got: input.len() });
        }
        let dir = self.t.direction;
        let inputs: Vec<&[C64]> = input.chunks(n).collect();
        let (mut outputs, report) = match &self.inner {
            Inner::Fftu(plan) => fftu_execute_batch(plan, &inputs, dir),
            Inner::Slab(plan) => plan.execute_batch_global(&inputs, dir),
            Inner::Pencil(plan) => plan.execute_batch_global(&inputs, dir),
            Inner::Heffte(plan) => plan.execute_batch_global(&inputs, dir),
            Inner::Popovici(plan) => plan.execute_batch_global(&inputs, dir),
        };
        let scale = self.t.normalization.scale(n);
        if scale != 1.0 {
            for out in &mut outputs {
                for v in out.iter_mut() {
                    *v = v.scale(scale);
                }
            }
        }
        let mut flat = Vec::with_capacity(input.len());
        for out in outputs {
            flat.extend(out);
        }
        Ok(Execution { output: flat, report })
    }
}

impl DistFft for PlannedFft {
    fn algorithm(&self) -> Algorithm {
        PlannedFft::algorithm(self)
    }

    fn transform(&self) -> &Transform {
        PlannedFft::transform(self)
    }

    fn procs(&self) -> usize {
        PlannedFft::procs(self)
    }

    fn grid(&self) -> Option<&[usize]> {
        PlannedFft::grid(self)
    }

    fn execute(&self, input: &[C64]) -> Result<Execution, FftError> {
        PlannedFft::execute(self, input)
    }

    fn execute_batch(&self, input: &[C64]) -> Result<Execution, FftError> {
        PlannedFft::execute_batch(self, input)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::fft::{dft_nd, rel_l2_error, Direction};
    use crate::testing::Rng;

    fn rand(n: usize, seed: u64) -> Vec<C64> {
        let mut rng = Rng::new(seed);
        (0..n).map(|_| C64::new(rng.f64_signed(), rng.f64_signed())).collect()
    }

    #[test]
    fn plan_resolves_auto_grid_for_cyclic_algorithms() {
        let t = Transform::new(&[16, 16]).procs(4);
        let p = plan(Algorithm::Fftu, &t).unwrap();
        assert_eq!(p.grid().unwrap().iter().product::<usize>(), 4);
        assert_eq!(p.procs(), 4);
        let p = plan(Algorithm::Popovici, &t).unwrap();
        assert_eq!(p.grid().unwrap().iter().product::<usize>(), 4);
    }

    #[test]
    fn execute_through_trait_object() {
        let t = Transform::new(&[8, 8]).procs(2);
        let planned: Arc<dyn DistFft> = plan(Algorithm::Fftu, &t).unwrap();
        let x = rand(64, 0xAB);
        let want = dft_nd(&x, &[8, 8], Direction::Forward);
        let got = planned.execute(&x).unwrap();
        assert!(rel_l2_error(&got.output, &want) < 1e-9);
        assert_eq!(got.report.comm_supersteps(), 1);
    }

    #[test]
    fn execute_rejects_wrong_length_with_typed_error() {
        let t = Transform::new(&[8, 8]).procs(2);
        let planned = plan(Algorithm::Fftu, &t).unwrap();
        assert_eq!(
            planned.execute(&[C64::ZERO; 10]).unwrap_err(),
            FftError::InputLength { expected: 64, got: 10 }
        );
        let batched = plan(Algorithm::Fftu, &Transform::new(&[8, 8]).procs(2).batch(3)).unwrap();
        assert_eq!(
            batched.execute_batch(&[C64::ZERO; 64]).unwrap_err(),
            FftError::InputLength { expected: 192, got: 64 }
        );
    }

    #[test]
    fn documented_comm_superstep_formulas() {
        assert_eq!(Algorithm::Fftu.comm_supersteps(3), 1);
        assert_eq!(Algorithm::slab().comm_supersteps(3), 2);
        assert_eq!(Algorithm::Slab { out: OutputDist::Different }.comm_supersteps(3), 1);
        assert_eq!(Algorithm::pencil(2).comm_supersteps(3), 3);
        assert_eq!(Algorithm::Pencil { r: 2, out: OutputDist::Different }.comm_supersteps(5), 1);
        assert_eq!(Algorithm::Heffte.comm_supersteps(3), 4);
        assert_eq!(Algorithm::Popovici.comm_supersteps(3), 3);
    }

    #[test]
    fn parse_round_trips_names() {
        for name in ["fftu", "slab", "pencil", "heffte", "popovici"] {
            assert_eq!(Algorithm::parse(name).unwrap().name(), name);
        }
        assert!(Algorithm::parse("nope").is_none());
    }
}
