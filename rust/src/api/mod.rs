//! The crate's front door: one plan/execute API over every distributed
//! FFT algorithm in the crate.
//!
//! The pieces:
//!
//! - [`Transform`] — the descriptor: shape, processor grid (explicit or
//!   [`Grid::Auto`] via `choose_grid`), [`Direction`], [`Normalization`],
//!   batch count, and [`Kind`] (complex c2c; real r2c/c2r via the
//!   packing trick — the complex core runs on the half shape, halving
//!   flops and communication volume; trig dct2/dct3/dst2/dst3 via
//!   Makhoul permutations and quarter-wave phases around the full-shape
//!   core);
//! - [`Algorithm`] — FFTU, any of the four published baselines
//!   (slab/FFTW, pencil/PFFT, heFFTe, Popovici), or [`Algorithm::Auto`]
//!   — the autotuning [`planner`], which prices every feasible
//!   (algorithm, grid, strategy) candidate against a
//!   [`crate::costmodel::Machine`] and plans the cheapest
//!   ([`Transform::auto`] is the one-call spelling);
//! - [`plan`] — plan-time validation returning a reusable
//!   [`PlannedFft`] (all algorithms implement [`DistFft`]);
//! - [`FftError`] — the typed error every fallible call returns;
//! - [`PlanCache`] — an LRU cache keyed by the descriptor, so repeated
//!   transforms reuse `FftuPlan`/baseline schedules instead of
//!   replanning.
//!
//! ```
//! use fftu::api::{Algorithm, DistFft, Normalization, PlanCache, Transform};
//! use fftu::fft::{max_abs_diff, C64};
//!
//! let x: Vec<C64> = (0..256).map(|i| C64::new(i as f64, -(i as f64))).collect();
//! let cache = PlanCache::new(8);
//!
//! // Forward FFTU on 4 auto-placed processors: ONE all-to-all.
//! let fwd = cache.plan(Algorithm::Fftu, &Transform::new(&[16, 16]).procs(4))?;
//! let y = fwd.execute(&x)?;
//! assert_eq!(y.report.comm_supersteps(), 1);
//!
//! // Inverse with explicit 1/N normalization: exact round trip.
//! let inv = cache.plan(
//!     Algorithm::Fftu,
//!     &Transform::new(&[16, 16]).procs(4).inverse().normalization(Normalization::ByN),
//! )?;
//! let z = inv.execute(&y.output)?;
//! assert!(max_abs_diff(&z.output, &x) < 1e-9);
//!
//! // Same descriptor, different algorithm: d communication supersteps.
//! let pop = cache.plan(Algorithm::Popovici, &Transform::new(&[16, 16]).procs(4))?;
//! assert_eq!(pop.execute(&x)?.report.comm_supersteps(), 2);
//! # Ok::<(), fftu::FftError>(())
//! ```

pub mod cache;
pub mod error;
pub mod plan;
pub mod planner;
pub mod transform;

pub use cache::{CacheStats, PlanCache};
pub use error::FftError;
pub use plan::{plan, Algorithm, BatchIo, BatchOut, DistFft, Execution, PlannedFft, RealExecution};
pub use planner::{plan_auto, PlannerMode, ScoredCandidate};
pub use transform::{DistStrategy, Grid, Kind, Normalization, Transform};

pub use crate::fft::Direction;
