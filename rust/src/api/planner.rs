//! The autotuning planner behind [`Algorithm::Auto`].
//!
//! §2 of the paper prices every algorithm in closed form, and
//! `costmodel` already evaluates those prices in `O(d * p)` without
//! touching data. This module turns that validation artifact into the
//! production scheduler, following FFTW's `Estimate`/`Measure` planning
//! idiom:
//!
//! - **Enumerate** every feasible candidate for the descriptor: FFTU
//!   over *every* admissible cyclic grid (`p_l^2 | n_l`, not just
//!   [`crate::fftu::choose_grid`]'s tie-break) under both the gathered
//!   and (for the real/trig kinds) zig-zag strategies, Popovici over
//!   the same grids, and the transpose baselines slab / pencil (every
//!   `1 <= r < d`) / heFFTe.
//! - **Price** each candidate's analytic [`crate::bsp::CostReport`]
//!   with [`Machine::predict`] (Eq. 2.12 extended with the §4.2 memory
//!   and startup terms). Candidates whose reports are infeasible for
//!   the shape, or whose predicted time is not finite, are dropped —
//!   a NaN must never win a `<` comparison.
//! - **Select** the minimum predicted time ([`PlannerMode::Estimate`]),
//!   or refine the analytic top-k with timed *warm* trial executes —
//!   plan once, run twice, keep the second run's time — and take the
//!   measured minimum ([`PlannerMode::Measure`]).
//!
//! The winner is planned through the ordinary [`plan`] entry point with
//! an explicit (algorithm, grid, strategy) descriptor, so an `Auto`
//! pick round-trips bit-identically against requesting the same
//! candidate by hand. The analytic model's feasibility is additionally
//! validated by planning itself: if the cheapest candidate fails to
//! plan, the next one is tried, so `Auto` never commits to an
//! infeasible schedule.

use std::sync::Arc;
use std::time::Instant;

use crate::baselines::OutputDist;
use crate::bsp::CostReport;
use crate::costmodel::{self, Machine};
use crate::fft::realnd::{half_shape, rfftn};
use crate::fft::C64;
use crate::fftu::{enumerate_grids, enumerate_grids_any, grid_feasible, zigzag};
use crate::testing::Rng;

use super::error::FftError;
use super::plan::{plan, Algorithm, PlannedFft};
use super::transform::{DistStrategy, Grid, Kind, Transform};

/// Planning rigor — FFTW's `Estimate`/`Measure` split.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum PlannerMode {
    /// Analytic only: price every feasible candidate with the cost
    /// model and commit to the minimum predicted time. No trial
    /// executes; planning stays `O(candidates * d * p)` on top of the
    /// winner's own plan construction.
    Estimate,
    /// Analytic shortlist plus timed warm trial executes of the
    /// `top_k` cheapest-predicted candidates (plan once, run twice,
    /// keep the second run's wall time); the measured minimum wins.
    /// `top_k` is clamped to at least 1 and at most the candidate
    /// count.
    Measure {
        /// How many analytic front-runners get a trial execute.
        top_k: usize,
    },
}

/// One priced planner candidate (a row of `cli run --algo auto
/// --verbose`'s table).
#[derive(Clone, Debug)]
pub struct ScoredCandidate {
    /// The concrete algorithm (never [`Algorithm::Auto`]).
    pub algorithm: Algorithm,
    /// Resolved per-axis cyclic grid for the cyclic family (on the
    /// packed half shape for r2c/c2r); `None` for the transpose
    /// baselines, which take only a processor count.
    pub grid: Option<Vec<usize>>,
    /// Wrapper-pass strategy (always `Gathered` for c2c).
    pub strategy: DistStrategy,
    /// Machine-predicted seconds per transform.
    pub predicted_s: f64,
    /// Warm trial-execute seconds ([`PlannerMode::Measure`] top-k
    /// candidates only).
    pub measured_s: Option<f64>,
}

impl ScoredCandidate {
    /// The explicit descriptor that requests exactly this candidate:
    /// the caller's descriptor with the candidate's grid pinned
    /// (`Grid::Explicit`) and its strategy substituted. Planning it
    /// through [`plan`] is bit-identical to what `Auto` executes.
    pub fn descriptor(&self, t: &Transform) -> Transform {
        let mut tc = t.clone();
        if let Some(g) = &self.grid {
            tc.grid = Grid::Explicit(g.clone());
        }
        if t.kind != Kind::C2C {
            tc.strategy = self.strategy;
        }
        tc
    }
}

/// Price one candidate's analytic ledger, mirroring
/// `PlannedFft::analytic_report` without constructing any plan. A
/// `Result::Err` is the cost model's own infeasibility verdict (e.g.
/// slab cannot split this shape over `p`).
fn price(
    t: &Transform,
    algorithm: Algorithm,
    grid: Option<&[usize]>,
    strategy: DistStrategy,
    p: usize,
) -> Result<CostReport, FftError> {
    fn c2c_price(
        algorithm: Algorithm,
        shape: &[usize],
        grid: Option<&[usize]>,
        p: usize,
    ) -> Result<CostReport, FftError> {
        match algorithm {
            Algorithm::Fftu => {
                let g = grid.expect("fftu candidates carry a grid");
                // Beyond-sqrt(N) grids price the k-superstep ladder
                // ledger; single-all-to-all grids keep Eq. (2.12).
                if g.iter().zip(shape).all(|(&q, &n)| n % (q * q) == 0) {
                    Ok(costmodel::fftu_report(shape, p))
                } else {
                    Ok(costmodel::fftu_ladder_report(shape, g))
                }
            }
            Algorithm::Slab { out } => {
                costmodel::slab_report(shape, p, out == OutputDist::Same)
            }
            Algorithm::Pencil { r, out } => {
                costmodel::pencil_report(shape, r, p, out == OutputDist::Same)
            }
            Algorithm::Heffte => costmodel::heffte_report(shape, p),
            Algorithm::Popovici => Ok(costmodel::popovici_report(
                shape,
                grid.expect("cyclic candidates carry a grid"),
            )),
            Algorithm::Auto => unreachable!("Auto never prices itself as a candidate"),
        }
    }
    let shape: &[usize] = &t.shape;
    if t.kind == Kind::C2C {
        return c2c_price(algorithm, shape, grid, p);
    }
    if strategy == DistStrategy::ZigZag {
        let g = grid.expect("zig-zag candidates are fftu, hence cyclic");
        return Ok(match t.kind {
            Kind::R2C => costmodel::fftu_r2c_zigzag_report(shape, g),
            Kind::C2R => costmodel::fftu_c2r_zigzag_report(shape, g),
            Kind::Dct2 | Kind::Dst2 => costmodel::fftu_trig_zigzag_report(shape, g, true),
            Kind::Dct3 | Kind::Dst3 => costmodel::fftu_trig_zigzag_report(shape, g, false),
            Kind::C2C => unreachable!("handled above"),
        });
    }
    // Gathered wrappers: the complex core runs on the packed half shape
    // (real FFT) or the full shape (trig), and the wrap pass is priced
    // on top — the same two-layer structure the executor charges.
    let core_shape: Vec<usize> =
        if t.kind.is_real_fft() { half_shape(shape) } else { shape.to_vec() };
    let core = c2c_price(algorithm, &core_shape, grid, p)?;
    Ok(match t.kind {
        Kind::R2C | Kind::C2R => costmodel::real_wrap_report(core, shape, p, t.kind),
        _ => costmodel::trig_wrap_report(core, shape, p),
    })
}

/// Enumerate every (algorithm, grid, strategy) candidate the descriptor
/// admits, before pricing. Deterministic order: FFTU grids
/// ([`choose_grid`](crate::fftu::choose_grid)'s pick first) under
/// gathered then zig-zag, Popovici over the same grids, then slab,
/// pencil (`r` ascending), heFFTe — a stable sort on equal predicted
/// costs therefore prefers the same plan an explicit request would get.
fn candidates(t: &Transform) -> Vec<(Algorithm, Option<Vec<usize>>, DistStrategy)> {
    let p = t.grid.procs();
    let d = t.shape.len();
    // The cyclic grid lives on the shape the core actually transforms.
    let core_shape: Vec<usize> =
        if t.kind.is_real_fft() { half_shape(&t.shape) } else { t.shape.clone() };
    // Single-all-to-all grids (`p_l^2 | n_l`) serve every cyclic-family
    // candidate; the wider ladder-feasible set (beyond sqrt(N)) serves
    // FFTU gathered only — the zig-zag combine passes and Popovici's
    // d-step schedule both assume the cyclic output placement.
    let is_single =
        |g: &[usize]| g.iter().zip(&core_shape).all(|(&q, &n)| q >= 1 && n % (q * q) == 0);
    let (grids, single_grids): (Vec<Vec<usize>>, Vec<Vec<usize>>) = match &t.grid {
        Grid::Explicit(g) => {
            // Respect a pinned grid, if the cyclic family can use it.
            let any_valid = g.len() == d && grid_feasible(&core_shape, g);
            let single_valid = g.len() == d && is_single(g);
            (
                if any_valid { vec![g.clone()] } else { Vec::new() },
                if single_valid { vec![g.clone()] } else { Vec::new() },
            )
        }
        Grid::Auto { .. } => {
            (enumerate_grids_any(&core_shape, p), enumerate_grids(&core_shape, p))
        }
    };
    // c2c has no wrapper passes, so no zig-zag variant; a descriptor
    // that explicitly asked for zig-zag restricts the search to it.
    let strategies: &[DistStrategy] = if t.kind == Kind::C2C {
        &[DistStrategy::Gathered]
    } else if t.strategy == DistStrategy::ZigZag {
        &[DistStrategy::ZigZag]
    } else {
        &[DistStrategy::Gathered, DistStrategy::ZigZag]
    };
    let mut out = Vec::new();
    for &strategy in strategies {
        let pool = if strategy == DistStrategy::ZigZag { &single_grids } else { &grids };
        for g in pool {
            if strategy == DistStrategy::ZigZag
                && t.kind.is_trig()
                && zigzag::validate_zigzag_axes(&t.shape, g).is_err()
            {
                continue;
            }
            out.push((Algorithm::Fftu, Some(g.clone()), strategy));
        }
    }
    for g in &single_grids {
        out.push((Algorithm::Popovici, Some(g.clone()), DistStrategy::Gathered));
    }
    if t.strategy != DistStrategy::ZigZag {
        // The transpose baselines only implement the gathered wrappers.
        out.push((Algorithm::slab(), None, DistStrategy::Gathered));
        for r in 1..d {
            out.push((Algorithm::pencil(r), None, DistStrategy::Gathered));
        }
        out.push((Algorithm::Heffte, None, DistStrategy::Gathered));
    }
    out
}

/// Time one warm execute of an already-constructed plan: inputs are
/// prepared outside the clock, the first execute builds the per-rank
/// workers and is discarded, the second is timed — FFTW's `Measure`
/// discipline (plan once, run twice, keep the second).
fn warm_trial_seconds(planned: &PlannedFft) -> Result<f64, FftError> {
    let t = planned.transform();
    let n = t.total();
    let mut rng = Rng::new(0xA070_7E57);
    match t.kind {
        Kind::C2C => {
            let x: Vec<C64> =
                (0..n).map(|_| C64::new(rng.f64_signed(), rng.f64_signed())).collect();
            planned.execute_one(&x)?;
            let t0 = Instant::now();
            planned.execute_one(&x)?;
            Ok(t0.elapsed().as_secs_f64())
        }
        Kind::R2C | Kind::Dct2 | Kind::Dct3 | Kind::Dst2 | Kind::Dst3 => {
            let x: Vec<f64> = (0..n).map(|_| rng.f64_signed()).collect();
            planned.execute_one(&x)?;
            let t0 = Instant::now();
            planned.execute_one(&x)?;
            Ok(t0.elapsed().as_secs_f64())
        }
        Kind::C2R => {
            // A valid Hermitian half-spectrum, built outside the clock.
            let x: Vec<f64> = (0..n).map(|_| rng.f64_signed()).collect();
            let spec = rfftn(&x, &t.shape);
            planned.execute_one(&spec)?;
            let t0 = Instant::now();
            planned.execute_one(&spec)?;
            Ok(t0.elapsed().as_secs_f64())
        }
    }
}

/// Plan `t` by exhaustive candidate pricing against `machine` (see the
/// module docs). This is what [`plan`] dispatches [`Algorithm::Auto`]
/// to, with [`Machine::planner_default`] and
/// [`PlannerMode::Estimate`]; call it directly to override either.
///
/// The returned plan carries [`Algorithm::Auto`] and the caller's
/// descriptor (so [`super::PlanCache`] keys repeat `auto` requests
/// identically), delegates every execute to the winner, and exposes
/// the decision through [`PlannedFft::chosen`] and
/// [`PlannedFft::planner_table`].
pub fn plan_auto(
    t: &Transform,
    machine: &Machine,
    mode: PlannerMode,
) -> Result<Arc<PlannedFft>, FftError> {
    t.validate()?;
    let p = t.grid.procs();
    let mut scored: Vec<ScoredCandidate> = candidates(t)
        .into_iter()
        .filter_map(|(algorithm, grid, strategy)| {
            let report = price(t, algorithm, grid.as_deref(), strategy, p).ok()?;
            let predicted_s = machine.predict(&report, p);
            // A non-finite price (e.g. a degenerate hand-rolled gap
            // curve) must not be allowed to "win" every comparison.
            if !predicted_s.is_finite() {
                return None;
            }
            Some(ScoredCandidate { algorithm, grid, strategy, predicted_s, measured_s: None })
        })
        .collect();
    if scored.is_empty() {
        return Err(FftError::Unsupported {
            reason: format!(
                "no feasible (algorithm, grid, strategy) candidate for shape {:?} on p = {p}",
                t.shape
            ),
        });
    }
    // Stable: equal predictions keep the enumeration preference order.
    scored.sort_by(|a, b| {
        a.predicted_s.partial_cmp(&b.predicted_s).expect("finite by construction")
    });

    if let PlannerMode::Measure { top_k } = mode {
        let k = top_k.clamp(1, scored.len());
        let mut best: Option<(f64, usize, Arc<PlannedFft>)> = None;
        for i in 0..k {
            let Ok(planned) = plan(scored[i].algorithm, &scored[i].descriptor(t)) else {
                continue;
            };
            let Ok(secs) = warm_trial_seconds(&planned) else { continue };
            scored[i].measured_s = Some(secs);
            if best.as_ref().map(|(b, _, _)| secs < *b).unwrap_or(true) {
                best = Some((secs, i, planned));
            }
        }
        if let Some((_, idx, chosen)) = best {
            return Ok(Arc::new(PlannedFft::new_auto(t.clone(), chosen, scored, idx)));
        }
        // Every shortlisted candidate failed to plan or run; fall
        // through to the analytic order below.
    }

    // Cheapest predicted candidate that actually plans wins — planning
    // is the authoritative feasibility check, so a cost-model row that
    // overstates what an algorithm supports cannot make Auto fail.
    let mut last_err = None;
    for i in 0..scored.len() {
        let (algorithm, descriptor) = (scored[i].algorithm, scored[i].descriptor(t));
        match plan(algorithm, &descriptor) {
            Ok(chosen) => {
                return Ok(Arc::new(PlannedFft::new_auto(t.clone(), chosen, scored, i)))
            }
            Err(e) => last_err = Some(e),
        }
    }
    Err(last_err.expect("scored is non-empty"))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::costmodel::GapCurve;

    #[test]
    fn candidates_cover_grids_strategies_and_baselines() {
        // c2c [64, 64] p=4: 3 fftu grids + 3 popovici + slab + pencil
        // r=1 + heffte, gathered only.
        let t = Transform::new(&[64, 64]).procs(4);
        let cands = candidates(&t);
        assert_eq!(cands.len(), 3 + 3 + 1 + 1 + 1);
        assert!(cands.iter().all(|(_, _, s)| *s == DistStrategy::Gathered));
        // The first candidate is FFTU on choose_grid's pick.
        assert_eq!(cands[0].0, Algorithm::Fftu);
        assert_eq!(cands[0].1.as_deref(), Some(&[2usize, 2][..]));
        // dct2 adds the zig-zag variants of the fftu grids.
        let t = Transform::new(&[64, 64]).procs(4).dct2();
        let zz = candidates(&t)
            .iter()
            .filter(|(_, _, s)| *s == DistStrategy::ZigZag)
            .count();
        assert_eq!(zz, 3);
        // An explicitly zig-zag descriptor restricts the search.
        let t = Transform::new(&[64, 64]).procs(4).dct2().zigzag();
        assert!(candidates(&t)
            .iter()
            .all(|(a, _, s)| *a == Algorithm::Fftu && *s == DistStrategy::ZigZag));
    }

    #[test]
    fn pricing_rejects_infeasible_candidates_not_the_whole_plan() {
        // [15, 15] with p = 3: no cyclic grid exists (3^2 does not
        // divide 15), but slab splits 15 rows over 3 ranks fine.
        let t = Transform::new(&[15, 15]).procs(3);
        let auto = plan_auto(&t, &Machine::planner_default(), PlannerMode::Estimate).unwrap();
        let chosen = auto.chosen().unwrap();
        assert!(!matches!(chosen.algorithm(), Algorithm::Fftu | Algorithm::Popovici));
    }

    #[test]
    fn extreme_machines_flip_the_choice() {
        let t = Transform::new(&[64, 64]).procs(4);
        // All communication free: only flops count, and FFTU's twiddle
        // superstep makes it strictly costlier than a transpose
        // baseline — the flop-minimal candidate wins.
        let free_comm = Machine {
            name: "free-comm",
            g_mem: 0.0,
            g_net: GapCurve::Const(0.0),
            l_sync: 0.0,
            t_msg: 0.0,
            ..Machine::snellius_like()
        };
        let auto = plan_auto(&t, &free_comm, PlannerMode::Estimate).unwrap();
        assert_ne!(auto.chosen().unwrap().algorithm(), Algorithm::Fftu);
        // A ruinously expensive network: the h-minimal candidate —
        // FFTU's single all-to-all of h = (N/p)(1 - 1/p) — wins.
        let wan = Machine {
            name: "wan",
            g_net: GapCurve::Const(1.0),
            ..Machine::snellius_like()
        };
        let auto = plan_auto(&t, &wan, PlannerMode::Estimate).unwrap();
        assert_eq!(auto.chosen().unwrap().algorithm(), Algorithm::Fftu);
    }

    #[test]
    fn measure_mode_times_the_shortlist() {
        let t = Transform::new(&[16, 16]).procs(4);
        let auto =
            plan_auto(&t, &Machine::planner_default(), PlannerMode::Measure { top_k: 3 })
                .unwrap();
        let table = auto.planner_table().unwrap();
        let measured = table.iter().filter(|c| c.measured_s.is_some()).count();
        assert!((1..=3).contains(&measured), "measured {measured} of top 3");
        // The winner is one of the measured candidates.
        let chosen = auto.chosen().unwrap();
        assert!(table.iter().any(|c| {
            c.measured_s.is_some()
                && c.algorithm == chosen.algorithm()
                && c.grid.as_deref() == chosen.grid()
        }));
    }
}
